"""Fused path-step Pallas TPU megakernel for the batched compact engine.

One flat step of the batched path engine (``core.batch``) is: form the
CONCORD smooth gradient from the cached product W = Omega S, take the
prox candidate at the lane's trial step size, and reduce the acceptance
dot products ``<diff, grad>`` / ``<diff, diff>`` plus the penalty-side
objective partials.  Done as jnp ops that is five-plus HBM passes over
every lane's p^2 state per trial; this kernel streams each tile of the
lane-stacked state through VMEM ONCE and emits the candidate plus all
per-tile reduction partials in the same pass.  Only the candidate's new
aux product (a matmul) and the smooth objective assembled from these
partials stay outside.

Layout: the C lanes are stacked tall — Omega and W arrive as
``(C * p, p)`` — and the grid is ``(C * p/bs, p/bs)`` square tiles with
``bs`` a divisor of p.  The transposed-W term of the gradient needs tile
``(j, i mod p/bs)`` of the SAME lane, fetched by a second BlockSpec on W
whose index map swaps the within-lane block coordinates (no transposed
copy of W is ever materialized).  Per-lane scalars ride in an SMEM
``(C, 3)`` table ``[tau, alpha = tau * lam1, lam2]`` indexed by the
lane id ``i // (p/bs)``.

Per-tile stats land in a ``(grid_m, grid_n, 128)`` lane-padded output
(lanes 0..4 = dot_dg, dot_dd, sumsq, l1_offdiag, nnz) that the wrapper
sum-reduces per lane; the nnz lane is the occupancy harvest.  The
elementwise candidate is bit-identical to the jitted ``ref.py`` oracle
(eager oracle dispatch fuses multiply-adds differently and can differ by
one ulp); the stats differ from a flat ``jnp.sum`` only by tile-order
association (the oracle equality test uses f64 and a tight allclose).

SCAD/MCP penalties are not representable as one scalar threshold per
lane, so the engine only routes soft-threshold-family penalties here
(``PenaltySpec.pallas_ok``) and falls back to the jnp trial otherwise.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .softthresh import STATS_MIN_DTYPE, STATS_LANES

#: preferred square tile edge; the actual edge is the largest divisor of
#: p not exceeding it (p itself when p is prime — interpret mode only)
DEFAULT_BLOCK = 256

#: stats lanes: [0]=<diff,grad> [1]=<diff,diff> [2]=||cand||_F^2
#: [3]=off-diagonal l1 of cand [4]=tile nnz of cand
N_STATS = 5


def _block_edge(p: int, block: int) -> int:
    bs = min(block, p)
    while p % bs:
        bs -= 1
    # no useful divisor (p prime, or coprime with everything <= block):
    # run the whole matrix as one tile rather than 1 x 1 confetti
    return p if bs == 1 and p > 1 else bs


def _tile_step(scal_ref, om, w, wt, wts, c, diag):
    """Shared per-tile math of both kernel bodies: gradient tile, prox
    candidate at the lane's tau, and the five reduction partials."""
    tau = scal_ref[c, 0]
    alpha = scal_ref[c, 1]
    lam2 = scal_ref[c, 2]
    grad = 0.5 * (w + wt) + lam2 * om
    grad = jnp.where(diag, grad - 1.0 / om, grad)
    z = om - tau * grad
    if wts is None:
        thr = alpha
    else:
        # inf weights force exact zeros even at alpha == 0 (inf*0 = nan)
        thr = jnp.where(jnp.isinf(wts), jnp.inf, alpha * wts)
    soft = jnp.sign(z) * jnp.maximum(jnp.abs(z) - thr, 0.0)
    cand = jnp.where(diag, z, soft)
    diff = cand - om
    return cand, (jnp.sum(diff * grad), jnp.sum(diff * diff),
                  jnp.sum(cand * cand),
                  jnp.sum(jnp.where(diag, 0.0, jnp.abs(cand))),
                  jnp.sum((cand != 0.0)))


def _write_stats(parts, stats_ref):
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, 1, STATS_LANES), 2)
    stats = jnp.zeros((1, 1, STATS_LANES), stats_ref.dtype)
    for k, v in enumerate(parts):
        stats = jnp.where(lane == k, v.astype(stats_ref.dtype), stats)
    stats_ref[...] = stats


def _diag_tile(bs: int, gpm: int):
    """Within-tile diagonal mask: tile (i, j) holds diagonal entries iff
    its within-lane block row ``i mod gpm`` equals its block column."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    on_diag_block = (i % gpm) == j
    r = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (bs, bs), 1)
    return (r == c) & on_diag_block


def _kernel(scal_ref, om_ref, w_ref, wt_ref, out_ref, stats_ref, *,
            bs: int, gpm: int):
    c = pl.program_id(0) // gpm
    diag = _diag_tile(bs, gpm)
    cand, parts = _tile_step(scal_ref, om_ref[...], w_ref[...],
                             wt_ref[...].T, None, c, diag)
    out_ref[...] = cand
    _write_stats(parts, stats_ref)


def _kernel_weighted(scal_ref, om_ref, w_ref, wt_ref, wts_ref, out_ref,
                     stats_ref, *, bs: int, gpm: int):
    c = pl.program_id(0) // gpm
    diag = _diag_tile(bs, gpm)
    cand, parts = _tile_step(scal_ref, om_ref[...], w_ref[...],
                             wt_ref[...].T, wts_ref[...], c, diag)
    out_ref[...] = cand
    _write_stats(parts, stats_ref)


def kernel_layout(c_lanes: int, p: int, *, weighted: bool = False,
                  block: int = DEFAULT_BLOCK) -> dict:
    """Grid + BlockSpec geometry of the path-step ``pallas_call``.

    Shared by the wrapper below and the CA4xx kernel verifier (via
    ``kernels.manifest``).  ``bs`` is the resolved tile edge (the prime-p
    full-tile fallback of :func:`_block_edge` included) and ``gpm`` the
    per-lane block count; the SMEM scalar table rides first in
    ``in_specs``, matching the call's operand order.
    """
    bs = _block_edge(p, block)
    gpm = p // bs
    gm, gn = c_lanes * gpm, gpm
    tile = pl.BlockSpec((bs, bs), lambda i, j: (i, j))
    # the transposed-W operand: within lane i // gpm, swap block coords
    tile_t = pl.BlockSpec(
        (bs, bs), lambda i, j: ((i // gpm) * gpm + j, i % gpm))
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM), tile, tile, tile_t]
    if weighted:
        in_specs.append(tile)
    return {
        "grid": (gm, gn),
        "in_specs": in_specs,
        "out_specs": [
            pl.BlockSpec((bs, bs), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1, STATS_LANES), lambda i, j: (i, j, 0)),
        ],
        "out_shapes": ((c_lanes * p, p), (gm, gn, STATS_LANES)),
        "bs": bs,
        "gpm": gpm,
    }


@partial(jax.jit, static_argnames=("block", "interpret"))
def fused_path_step(omega: jax.Array, w: jax.Array, tau, lam1, lam2,
                    *, weights=None, block: int = DEFAULT_BLOCK,
                    interpret: bool = True):
    """One fused flat step for C stacked lanes.

    ``omega``/``w`` are (C, p, p) iterates and their cached aux products
    W = Omega S; ``tau``/``lam1``/``lam2`` are (C,) per-lane scalars.
    ``weights`` (optional (C, p, p)) switches the prox to the weighted-l1
    threshold.  Returns ``(cand, stats)`` with ``cand`` (C, p, p) the prox
    candidates and ``stats`` (C, 5) the per-lane reductions
    ``[<diff,grad>, <diff,diff>, ||cand||_F^2, l1_offdiag, nnz]``.
    """
    c_lanes, p, _ = omega.shape
    dtype = omega.dtype
    lay = kernel_layout(c_lanes, p, weighted=weights is not None,
                        block=block)
    bs, gpm = lay["bs"], lay["gpm"]
    gm, gn = lay["grid"]
    scal = jnp.stack([
        jnp.broadcast_to(jnp.asarray(tau, dtype), (c_lanes,)),
        jnp.broadcast_to(jnp.asarray(tau * lam1, dtype), (c_lanes,)),
        jnp.broadcast_to(jnp.asarray(lam2, dtype), (c_lanes,)),
    ], axis=1)
    om2 = omega.reshape(c_lanes * p, p)
    w2 = w.reshape(c_lanes * p, p)
    stats_dtype = jnp.promote_types(dtype, STATS_MIN_DTYPE)
    out_shape = [
        jax.ShapeDtypeStruct(lay["out_shapes"][0], dtype),
        jax.ShapeDtypeStruct(lay["out_shapes"][1], stats_dtype),
    ]
    kw = dict(grid=lay["grid"], in_specs=lay["in_specs"],
              out_specs=lay["out_specs"], out_shape=out_shape,
              interpret=interpret)
    if weights is None:
        cand, stats = pl.pallas_call(
            partial(_kernel, bs=bs, gpm=gpm), **kw)(scal, om2, w2, w2)
    else:
        wts = jnp.asarray(weights, dtype)
        if wts.shape != omega.shape:
            raise ValueError(f"weights shape {wts.shape} must match the "
                             f"lane-stacked iterate shape {omega.shape}")
        cand, stats = pl.pallas_call(
            partial(_kernel_weighted, bs=bs, gpm=gpm),
            **kw)(scal, om2, w2, w2, wts.reshape(c_lanes * p, p))
    per_lane = stats.reshape(c_lanes, gpm, gn, STATS_LANES).sum(axis=(1, 2))
    return cand.reshape(c_lanes, p, p), per_lane[:, :N_STATS]
