"""Pallas TPU kernels for the compute hot-spots (+ pure-jnp oracles).

``softthresh``          — fused prox update + objective reductions.
``blocksparse_matmul``  — block-CSR x dense (TPU-native sparse-dense).
``flash_attention``     — GQA/causal/SWA/softcap flash attention.
``ops``                 — jit'd wrappers (interpret=True on CPU).
``ref``                 — oracles the kernels are sweep-tested against.
"""
from . import ops, ref  # noqa: F401
