"""jit'd public wrappers for the Pallas kernels.

Interpret mode is resolved lazily, per call: kernels compile natively when
the active jax backend is TPU and run in interpret mode (kernel bodies
executed as jax ops, for correctness validation) everywhere else.  The
check happens at call time, NOT at import time, so importing this module
never initializes the jax backend and later backend selection (e.g.
``jax.config.update("jax_platforms", ...)`` after import) is honored.

``set_interpret(True/False)`` pins an explicit module-level override
(``set_interpret(None)`` restores the backend-derived default), and every
wrapper still accepts an explicit ``interpret=`` keyword that wins over
both.
"""
from __future__ import annotations

import jax

from . import blocksparse_matmul as _bsmm
from . import flash_attention as _fa
from . import pathstep as _ps
from . import softthresh as _st

# Explicit override: None = decide from the active backend at call time.
_INTERPRET_OVERRIDE: bool | None = None


def set_interpret(value: bool | None) -> None:
    """Pin interpret mode for all kernel wrappers (None = auto per call)."""
    global _INTERPRET_OVERRIDE
    if value is not None and not isinstance(value, bool):
        raise TypeError(f"interpret override must be bool or None, got "
                        f"{value!r}")
    _INTERPRET_OVERRIDE = value


def reset_interpret() -> None:
    """Drop any pinned override: equivalent to ``set_interpret(None)``.

    Tests use the autouse conftest guard built on this so a test that
    pins interpret mode can never leak the pin into later tests."""
    set_interpret(None)


def interpret_default() -> bool:
    """Interpret unless overridden or actually running on TPU."""
    if _INTERPRET_OVERRIDE is not None:
        return _INTERPRET_OVERRIDE
    return jax.default_backend() != "tpu"


def fused_prox(z, diag_mask, alpha, **kw):
    kw.setdefault("interpret", interpret_default())
    return _st.fused_prox(z, diag_mask, alpha, **kw)


def fused_prox_stats(z, diag_mask, alpha, **kw):
    kw.setdefault("interpret", interpret_default())
    return _st.fused_prox_stats(z, diag_mask, alpha, **kw)


def fused_path_step(omega, w, tau, lam1, lam2, **kw):
    kw.setdefault("interpret", interpret_default())
    return _ps.fused_path_step(omega, w, tau, lam1, lam2, **kw)


def blocksparse_matmul(values, row_idx, col_idx, b, **kw):
    kw.setdefault("interpret", interpret_default())
    return _bsmm.blocksparse_matmul(values, row_idx, col_idx, b, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", interpret_default())
    return _fa.flash_attention(q, k, v, **kw)


# ---------------------------------------------------------------------------
# analysis manifest (repro.analysis.jaxprpass)
# ---------------------------------------------------------------------------

def _analysis_fused_prox():
    import jax.numpy as jnp
    p = 8
    z = jnp.linspace(-1.0, 1.0, p * p, dtype=jnp.float64).reshape(p, p)
    dm = jnp.eye(p, dtype=jnp.float64)

    def run(z_, dm_):
        return fused_prox_stats(z_, dm_, 0.1, block=(4, 4), interpret=True)

    return {"fn": run, "args": (z, dm)}


def _analysis_fused_path_step():
    import jax.numpy as jnp
    c, p = 2, 8
    om = (jnp.eye(p, dtype=jnp.float64)[None]
          + 0.01 * jnp.arange(c * p * p, dtype=jnp.float64
                              ).reshape(c, p, p) / (c * p * p))
    w = om * 1.5
    tau = jnp.full((c,), 0.5, jnp.float64)
    lam = jnp.full((c,), 0.1, jnp.float64)

    def run(om_, w_, tau_, lam_):
        return fused_path_step(om_, w_, tau_, lam_, lam_, block=4,
                               interpret=True)

    return {"fn": run, "args": (om, w, tau, lam)}


#: the Pallas prox dispatch in interpret mode: the kernel body is traced
#: as jax ops, so its stats lanes are covered by the f64 downcast check
ANALYSIS_ENTRIES = [
    {"name": "kernels.ops.fused_prox_stats",
     "path": "src/repro/kernels/softthresh.py", "axis_names": (),
     "build": _analysis_fused_prox},
    {"name": "kernels.ops.fused_path_step",
     "path": "src/repro/kernels/pathstep.py", "axis_names": (),
     "build": _analysis_fused_path_step},
]
