"""jit'd public wrappers for the Pallas kernels.

On a real TPU set ``repro.kernels.ops.INTERPRET = False`` (or pass
``interpret=False``); this container is CPU-only so interpret mode is the
default, executing the kernel bodies in Python for correctness validation.
"""
from __future__ import annotations

import jax

from . import blocksparse_matmul as _bsmm
from . import flash_attention as _fa
from . import softthresh as _st

# Interpret unless we are actually on TPU.
INTERPRET = jax.default_backend() != "tpu"


def fused_prox(z, diag_mask, alpha, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _st.fused_prox(z, diag_mask, alpha, **kw)


def fused_prox_stats(z, diag_mask, alpha, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _st.fused_prox_stats(z, diag_mask, alpha, **kw)


def blocksparse_matmul(values, row_idx, col_idx, b, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _bsmm.blocksparse_matmul(values, row_idx, col_idx, b, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _fa.flash_attention(q, k, v, **kw)
