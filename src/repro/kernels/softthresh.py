"""Fused proximal-update Pallas TPU kernel.

One VMEM pass computes  out = S_alpha(z) offdiag + z diag  AND the
objective reduction pieces the line search needs (log-det over the
diagonal, off-diagonal l1, Frobenius sum-of-squares, diagonal min for the
positivity guard).  The paper's CPU code makes 3+ passes over the p^2
iterate for these elementwise steps; on TPU the whole state is streamed
HBM->VMEM once per line-search trial.

The kernel has an optional WEIGHT operand lane for the composable penalty
API (``core.penalty``): with ``weights`` the per-entry threshold becomes
``alpha * w_ij`` (``w_ij = inf`` forces an exact zero, the structural-
exclusion convention), streamed through VMEM alongside the iterate.
Without it the scalar-broadcast fast path is byte-for-byte the original
kernel — no extra HBM traffic, bit-identical output.

Tiles are (block_m, block_n) VMEM blocks; the per-tile partial stats land
in a (grid_m, grid_n, 128) output (TPU lane-padded; only lanes 0..4 carry
data) that the wrapper reduces.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (256, 256)
# lane-aligned stats vector;
#   [0]=logdet [1]=l1 [2]=sumsq [3]=min_diag [4]=tile nnz count
# lane 4 is the free block-occupancy harvest: with block == the matops
# block size, stats[..., 4] > 0 IS the block-sparse dispatch mask.
# (lane 1 stays the UNWEIGHTED |out| sum in the weighted kernel.)
STATS_LANES = 128

#: floor dtype of the per-tile stats output: at least f32 (counts and
#: reductions would drift in bf16), widened to the operand dtype so an
#: f64 interpret-mode solve keeps f64 line-search stats end to end.
STATS_MIN_DTYPE = jnp.float32


def _write_stats(out, m, valid, stats_ref):
    is_diag = m > 0
    logdet = jnp.sum(jnp.where(is_diag, jnp.log(jnp.maximum(out, 1e-30)), 0.0))
    l1 = jnp.sum(jnp.where(is_diag, 0.0, jnp.abs(out)))
    sumsq = jnp.sum(out * out)
    min_diag = jnp.min(jnp.where(is_diag, out, jnp.inf))
    nnz = jnp.sum(((out != 0.0) & valid).astype(stats_ref.dtype))
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, 1, STATS_LANES), 2)
    stats = jnp.where(lane == 0, logdet, 0.0)
    stats = jnp.where(lane == 1, l1, stats)
    stats = jnp.where(lane == 2, sumsq, stats)
    stats = jnp.where(lane == 3, min_diag, stats)
    stats = jnp.where(lane == 4, nnz, stats)
    stats_ref[...] = stats.astype(stats_ref.dtype)


def _tile_valid(shape, nrows, ncols):
    # mask out-of-bounds lanes of edge tiles (padding must not reach the
    # reductions)
    bm, bn = shape
    grow = pl.program_id(0) * bm + jax.lax.broadcasted_iota(
        jnp.int32, (bm, bn), 0)
    gcol = pl.program_id(1) * bn + jax.lax.broadcasted_iota(
        jnp.int32, (bm, bn), 1)
    return (grow < nrows) & (gcol < ncols)


def _kernel(alpha_ref, z_ref, mask_ref, out_ref, stats_ref, *, nrows, ncols):
    valid = _tile_valid(z_ref.shape, nrows, ncols)
    z = jnp.where(valid, z_ref[...], 0.0)
    m = jnp.where(valid, mask_ref[...], 0.0)
    alpha = alpha_ref[0]
    st = jnp.sign(z) * jnp.maximum(jnp.abs(z) - alpha, 0.0)
    out = st * (1.0 - m) + z * m
    out_ref[...] = out
    _write_stats(out, m, valid, stats_ref)


def _kernel_weighted(alpha_ref, z_ref, mask_ref, w_ref, out_ref, stats_ref,
                     *, nrows, ncols):
    valid = _tile_valid(z_ref.shape, nrows, ncols)
    z = jnp.where(valid, z_ref[...], 0.0)
    m = jnp.where(valid, mask_ref[...], 0.0)
    w = jnp.where(valid, w_ref[...], 0.0)
    alpha = alpha_ref[0]
    # inf weights must force exact zeros even at alpha == 0 (inf*0 = nan)
    thr = jnp.where(jnp.isinf(w), jnp.inf, alpha * w)
    st = jnp.sign(z) * jnp.maximum(jnp.abs(z) - thr, 0.0)
    out = st * (1.0 - m) + z * m
    out_ref[...] = out
    _write_stats(out, m, valid, stats_ref)


def kernel_layout(m: int, n: int, *, weighted: bool = False,
                  block=DEFAULT_BLOCK) -> dict:
    """Grid + BlockSpec geometry of the fused-prox ``pallas_call``.

    The single source the wrapper below AND the CA4xx kernel verifier
    (``repro.analysis.pallaspass``, via ``kernels.manifest``) share: the
    verifier enumerates ``grid`` and evaluates every index map returned
    here, so a layout edit is checked exactly as it ships.  ``in_specs``
    lists the SMEM alpha table first, matching the operand order of the
    call; ``out_shapes`` are the logical (unpadded) output array shapes.
    """
    bm = min(block[0], m)
    bn = min(block[1], n)
    gm, gn = pl.cdiv(m, bm), pl.cdiv(n, bn)
    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM), tile, tile]
    if weighted:
        in_specs.append(tile)
    return {
        "grid": (gm, gn),
        "in_specs": in_specs,
        "out_specs": [
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1, STATS_LANES), lambda i, j: (i, j, 0)),
        ],
        "out_shapes": ((m, n), (gm, gn, STATS_LANES)),
    }


@partial(jax.jit, static_argnames=("block", "interpret"))
def fused_prox_stats(z: jax.Array, diag_mask: jax.Array, alpha,
                     *, weights=None, block=DEFAULT_BLOCK,
                     interpret: bool = True):
    """Returns (out, logdet, l1_offdiag, sumsq, min_diag, block_nnz).

    ``block_nnz`` is the (grid_m, grid_n) per-tile nonzero count of the
    prox output — with ``block`` set to the matops block size it is the
    block-occupancy mask the sparse matmul dispatch consumes, harvested
    in the same HBM pass as the prox itself.

    ``weights`` (optional, (m, n)) switches the threshold to
    ``alpha * weights`` elementwise (the weighted-l1/adaptive-lasso lane);
    ``None`` keeps the scalar-broadcast fast path."""
    m, n = z.shape
    lay = kernel_layout(m, n, weighted=weights is not None, block=block)
    alpha_arr = jnp.asarray(alpha, z.dtype).reshape(1)
    stats_dtype = jnp.promote_types(z.dtype, STATS_MIN_DTYPE)
    out_shape = [
        jax.ShapeDtypeStruct(lay["out_shapes"][0], z.dtype),
        jax.ShapeDtypeStruct(lay["out_shapes"][1], stats_dtype),
    ]
    kw = dict(grid=lay["grid"], in_specs=lay["in_specs"],
              out_specs=lay["out_specs"], out_shape=out_shape,
              interpret=interpret)
    if weights is None:
        out, stats = pl.pallas_call(
            partial(_kernel, nrows=m, ncols=n), **kw,
        )(alpha_arr, z, diag_mask)
    else:
        w = jnp.asarray(weights, z.dtype)
        if w.shape != z.shape:
            raise ValueError(
                f"weights shape {w.shape} must match the iterate shape "
                f"{z.shape}")
        out, stats = pl.pallas_call(
            partial(_kernel_weighted, nrows=m, ncols=n), **kw,
        )(alpha_arr, z, diag_mask, w)
    logdet = jnp.sum(stats[..., 0])
    l1 = jnp.sum(stats[..., 1])
    sumsq = jnp.sum(stats[..., 2])
    min_diag = jnp.min(stats[..., 3])
    block_nnz = stats[..., 4]
    return out, logdet, l1, sumsq, min_diag, block_nnz


@partial(jax.jit, static_argnames=("block", "interpret"))
def fused_prox(z: jax.Array, diag_mask: jax.Array, alpha,
               *, weights=None, block=DEFAULT_BLOCK, interpret: bool = True):
    """Prox only (no stats) — the distributed drivers' inner step."""
    return fused_prox_stats(z, diag_mask, alpha, weights=weights,
                            block=block, interpret=interpret)[0]
