"""Kernel verification manifest: the ``KERNEL_ENTRIES`` registry.

Every ``pallas_call`` site in this package registers itself here with

  * its concrete grid/BlockSpec geometry at a set of representative
    configurations (aligned tiles, edge tiles, the prime-p full-tile
    fallback, inf-guarded weight lanes), built from the SAME
    ``kernel_layout()`` helper the kernel's own wrapper consumes — the
    verifier checks exactly what ships;
  * its ``ref.py`` oracle twin and a declared tolerance class
    (``bit-exact`` or ``fp-tolerant``, mirroring the CA30x contract
    pattern);
  * a seeded differential-fuzz builder that runs the kernel in interpret
    mode against the jitted oracle at each configuration.

The static CA4xx engine (:mod:`repro.analysis.pallaspass`) enumerates
each configuration's grid and evaluates every index map at every grid
point; the differential sanitizer (:mod:`repro.analysis.kernelfuzz`)
executes the fuzz builders and enforces the tolerance classes.

Entry schema (one dict per kernel module)::

    {
      "name": "kernels.softthresh.fused_prox_stats",   # finding context
      "path": "src/repro/kernels/softthresh.py",       # finding location
      "oracle": "fused_prox_stats",   # attribute of kernels.ref (CA405)
      "tolerance": "bit-exact",       # class of the PRIMARY output
      "rtol": 1e-11, "atol": 1e-11,   # fp-tolerant comparison knobs
      "f64_contract": True,           # CA404 traces the kernel at f64
      "configs": ({"label": "aligned", ...}, ...),   # parameter grid
      "layout": cfg -> KernelLayout,  # concrete geometry (CA401/2/3/6)
      "fuzz": (cfg, np_rng) -> [(out_name, got, want, tol_class), ...],
      "trace": optional () -> {"fn": callable, "args": tuple},  # CA404
      "skip": ("CA4xx", ...),         # optional per-entry opt-outs
    }

``layout``/``fuzz``/``trace`` are thunks taking only manifest data, so
importing this module never builds arrays or touches the backend.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

#: the tolerance classes a kernel may declare for its oracle twin:
#: ``bit-exact`` outputs are compared with assert_array_equal, while
#: ``fp-tolerant`` outputs use allclose at the entry's rtol/atol
TOLERANCE_CLASSES = ("bit-exact", "fp-tolerant")

#: kernel-package files shared by every entry — a git diff touching one
#: of these invalidates the whole registry under ``--changed`` scoping
SHARED_KERNEL_FILES = (
    "src/repro/kernels/manifest.py",
    "src/repro/kernels/ops.py",
    "src/repro/kernels/ref.py",
)


@dataclass(frozen=True)
class BlockArg:
    """One ``pallas_call`` operand: logical array shape + BlockSpec.

    ``spec.block_shape is None`` marks an SMEM scalar-table operand (no
    index map; bounds come from ``KernelLayout.scalar_rows``)."""
    name: str
    shape: tuple
    spec: object


@dataclass(frozen=True)
class KernelLayout:
    """Concrete geometry of one ``pallas_call`` at one manifest config.

    ``prefetch`` holds the scalar-prefetch arrays appended to every
    index-map call (PrefetchScalarGridSpec semantics).  ``sequential``
    maps an output position to the frozenset of grid dims the kernel
    DECLARES as in-order accumulation over that output — revisiting an
    output block along any other dim is a CA401 write race, and even a
    declared revisit must be one contiguous run of grid steps (the
    output tile is flushed when its block index changes).
    ``scalar_rows`` maps an SMEM input position to the minimum leading
    table extent the kernel body indexes (CA406)."""
    grid: tuple
    inputs: tuple
    outputs: tuple
    prefetch: tuple = ()
    sequential: dict = field(default_factory=dict)
    scalar_rows: dict = field(default_factory=dict)


# ---------------------------------------------------------------------------
# jitted oracles (jit wrapping is lazy: no backend touch at import)
# ---------------------------------------------------------------------------

def _jit_oracles():
    import jax

    from . import ref
    return {
        "fused_prox_stats": jax.jit(ref.fused_prox_stats,
                                    static_argnames=("block",)),
        "fused_path_step": jax.jit(ref.fused_path_step),
        "blocksparse_matmul": jax.jit(ref.blocksparse_matmul,
                                      static_argnames=("p",)),
        "attention": jax.jit(ref.attention,
                             static_argnames=("causal", "window",
                                              "softcap", "scale")),
    }


# ---------------------------------------------------------------------------
# softthresh (fused prox + stats)
# ---------------------------------------------------------------------------

def _softthresh_layout(cfg) -> KernelLayout:
    from . import softthresh as st
    m, n = cfg["m"], cfg["n"]
    weighted = bool(cfg.get("weighted"))
    lay = st.kernel_layout(m, n, weighted=weighted,
                           block=tuple(cfg["block"]))
    gm, gn = lay["grid"]
    inputs = [BlockArg("alpha", (1,), lay["in_specs"][0]),
              BlockArg("z", (m, n), lay["in_specs"][1]),
              BlockArg("diag_mask", (m, n), lay["in_specs"][2])]
    if weighted:
        inputs.append(BlockArg("weights", (m, n), lay["in_specs"][3]))
    return KernelLayout(
        grid=lay["grid"],
        inputs=tuple(inputs),
        outputs=(BlockArg("out", lay["out_shapes"][0], lay["out_specs"][0]),
                 BlockArg("stats", lay["out_shapes"][1],
                          lay["out_specs"][1])),
        scalar_rows={0: 1},
    )


def _softthresh_problem(cfg, rng):
    m, n = cfg["m"], cfg["n"]
    z = rng.standard_normal((m, n))
    pm = min(m, n)
    idx = np.arange(pm)
    z[idx, idx] = np.abs(z[idx, idx]) + 0.1     # positive diag for logdet
    mask = np.zeros((m, n))
    mask[idx, idx] = 1.0
    weights = None
    if cfg.get("weighted"):
        w = np.abs(rng.standard_normal((m, n))) + 0.1
        w[rng.random((m, n)) < 0.15] = np.inf   # structural exclusions
        weights = w
    return z, mask, weights


def _softthresh_fuzz(cfg, rng):
    import jax.numpy as jnp

    from . import ops
    z, mask, weights = _softthresh_problem(cfg, rng)
    dtype = jnp.float64
    za, ma = jnp.asarray(z, dtype), jnp.asarray(mask, dtype)
    wa = None if weights is None else jnp.asarray(weights, dtype)
    alpha = cfg.get("alpha", 0.3)
    block = tuple(cfg["block"])
    got = ops.fused_prox_stats(za, ma, alpha, weights=wa, block=block,
                               interpret=True)
    want = _jit_oracles()["fused_prox_stats"](za, ma, alpha, weights=wa,
                                              block=block)
    names = ("out", "logdet", "l1_offdiag", "sumsq", "min_diag",
             "block_nnz")
    # the elementwise outputs and the order-free reductions (min, exact
    # counts) are bit-identical to the jitted oracle; the tile-summed
    # scalars differ only by association order
    classes = ("bit-exact", "fp-tolerant", "fp-tolerant", "fp-tolerant",
               "bit-exact", "bit-exact")
    return [(nm, g, w, cl)
            for nm, g, w, cl in zip(names, got, want, classes)]


def _softthresh_trace():
    import jax.numpy as jnp

    from . import softthresh as st
    p = 8
    z = jnp.linspace(-1.0, 1.0, p * p, dtype=jnp.float64).reshape(p, p)
    dm = jnp.eye(p, dtype=jnp.float64)
    return {"fn": lambda z_, dm_: st.fused_prox_stats(
                z_, dm_, 0.1, block=(4, 4), interpret=True),
            "args": (z, dm)}


# ---------------------------------------------------------------------------
# pathstep (fused path-step megakernel)
# ---------------------------------------------------------------------------

def _pathstep_layout(cfg) -> KernelLayout:
    from . import pathstep as ps
    c, p = cfg["c"], cfg["p"]
    weighted = bool(cfg.get("weighted"))
    lay = ps.kernel_layout(c, p, weighted=weighted, block=cfg["block"])
    flat = (c * p, p)
    inputs = [BlockArg("scal", (c, 3), lay["in_specs"][0]),
              BlockArg("omega", flat, lay["in_specs"][1]),
              BlockArg("w", flat, lay["in_specs"][2]),
              BlockArg("w_t", flat, lay["in_specs"][3])]
    if weighted:
        inputs.append(BlockArg("weights", flat, lay["in_specs"][4]))
    return KernelLayout(
        grid=lay["grid"],
        inputs=tuple(inputs),
        outputs=(BlockArg("cand", lay["out_shapes"][0],
                          lay["out_specs"][0]),
                 BlockArg("stats", lay["out_shapes"][1],
                          lay["out_specs"][1])),
        scalar_rows={0: c},
    )


def _pathstep_problem(cfg, rng):
    c, p = cfg["c"], cfg["p"]
    om = 0.1 * rng.standard_normal((c, p, p))
    idx = np.arange(p)
    om[:, idx, idx] = np.abs(om[:, idx, idx]) + 1.0   # safe 1/omega diag
    w = rng.standard_normal((c, p, p))
    tau = 0.3 + 0.1 * np.arange(c)
    lam1 = 0.05 + 0.02 * np.arange(c)
    lam2 = np.full(c, 0.01)
    weights = None
    if cfg.get("weighted"):
        wt = np.abs(rng.standard_normal((c, p, p))) + 0.1
        wt[rng.random((c, p, p)) < 0.15] = np.inf
        weights = wt
        if cfg.get("zero_lam1_lane"):
            lam1[0] = 0.0      # inf-guard: inf * 0 must still force zeros
    return om, w, tau, lam1, lam2, weights


def _pathstep_fuzz(cfg, rng):
    import jax.numpy as jnp

    from . import ops
    om, w, tau, lam1, lam2, weights = _pathstep_problem(cfg, rng)
    dtype = jnp.float64
    oma, wa = jnp.asarray(om, dtype), jnp.asarray(w, dtype)
    taua, l1a, l2a = (jnp.asarray(v, dtype) for v in (tau, lam1, lam2))
    wta = None if weights is None else jnp.asarray(weights, dtype)
    got = ops.fused_path_step(oma, wa, taua, l1a, l2a, weights=wta,
                              block=cfg["block"], interpret=True)
    want = _jit_oracles()["fused_path_step"](oma, wa, taua, l1a, l2a,
                                             weights=wta)
    # the candidate is bit-identical to the jitted oracle (same op order
    # per element); the (C, 5) stats differ by tile summation order
    return [("cand", got[0], want[0], "bit-exact"),
            ("stats", got[1], want[1], "fp-tolerant")]


def _pathstep_trace():
    import jax.numpy as jnp

    from . import pathstep as ps
    c, p = 2, 8
    om = (jnp.eye(p, dtype=jnp.float64)[None]
          + 0.01 * jnp.arange(c * p * p, dtype=jnp.float64
                              ).reshape(c, p, p) / (c * p * p))
    w = om * 1.5
    tau = jnp.full((c,), 0.5, jnp.float64)
    lam = jnp.full((c,), 0.1, jnp.float64)
    return {"fn": lambda om_, w_, tau_, lam_: ps.fused_path_step(
                om_, w_, tau_, lam_, lam_, block=4, interpret=True),
            "args": (om, w, tau, lam)}


# ---------------------------------------------------------------------------
# blocksparse_matmul (block-CSR x dense)
# ---------------------------------------------------------------------------

def _bsr_problem(cfg, rng):
    from . import ref
    p, bs = cfg["p"], cfg["bs"]
    nbr = p // bs
    a = rng.standard_normal((p, p))
    keep = rng.random((nbr, nbr)) < cfg["density"]
    for r in range(nbr):
        for c in range(nbr):
            if not keep[r, c]:
                a[r * bs:(r + 1) * bs, c * bs:(c + 1) * bs] = 0.0
    vals, rows, cols = ref.dense_to_block_csr(a, bs)
    b = rng.standard_normal((p, cfg["m"]))
    return a, vals, rows, cols, b


def _blocksparse_layout(cfg) -> KernelLayout:
    from . import blocksparse_matmul as bsmm
    # the prefetch row/col ids are part of the geometry: derive them from
    # the config's seeded problem, exactly as the fuzz harness does
    _, vals, rows, cols, _ = _bsr_problem(
        cfg, np.random.default_rng(cfg.get("seed", 0)))
    nb, bs = vals.shape[0], cfg["bs"]
    p, m = cfg["p"], cfg["m"]
    lay = bsmm.kernel_layout(nb, bs, p, m, block_n=cfg["block_n"])
    return KernelLayout(
        grid=lay["grid"],
        inputs=(BlockArg("values", (nb, bs, bs), lay["in_specs"][0]),
                BlockArg("b", (p, m), lay["in_specs"][1])),
        outputs=(BlockArg("out", lay["out_shapes"][0],
                          lay["out_specs"]),),
        prefetch=(rows, cols),
        # the nnz sweep (grid dim 1) accumulates into out in CSR order:
        # declared sequential, so only NON-contiguous row revisits race
        sequential={0: frozenset({1})},
    )


def _blocksparse_fuzz(cfg, rng):
    import jax.numpy as jnp

    from . import ops
    _, vals, rows, cols, b = _bsr_problem(cfg, rng)
    dtype = jnp.float64
    va, ba = jnp.asarray(vals, dtype), jnp.asarray(b, dtype)
    ra, ca = jnp.asarray(rows), jnp.asarray(cols)
    got = ops.blocksparse_matmul(va, ra, ca, ba, block_n=cfg["block_n"],
                                 interpret=True)
    want = _jit_oracles()["blocksparse_matmul"](va, ra, ca, ba,
                                                p=cfg["p"])
    # VMEM per-block accumulation vs the oracle's dense matmul: same
    # values, different association order
    return [("out", got, want, "fp-tolerant")]


def _blocksparse_trace():
    import jax.numpy as jnp

    from . import blocksparse_matmul as bsmm
    vals = jnp.arange(2 * 4 * 4, dtype=jnp.float64).reshape(2, 4, 4)
    rows = jnp.asarray([0, 1], jnp.int32)
    cols = jnp.asarray([1, 0], jnp.int32)
    b = jnp.ones((8, 8), jnp.float64)
    return {"fn": lambda v, b_: bsmm.blocksparse_matmul(
                v, rows, cols, b_, block_n=4, interpret=True),
            "args": (vals, b)}


# ---------------------------------------------------------------------------
# flash_attention (online-softmax attention)
# ---------------------------------------------------------------------------

def _flash_layout(cfg) -> KernelLayout:
    from . import flash_attention as fa
    B, Hq, Hkv = cfg["B"], cfg["Hq"], cfg["Hkv"]
    Lq, Lkv, D = cfg["Lq"], cfg["Lkv"], cfg["D"]
    lay = fa.kernel_layout(B, Hq, Hkv, Lq, Lkv, D,
                           block_q=cfg["block_q"], block_k=cfg["block_k"])
    return KernelLayout(
        grid=lay["grid"],
        inputs=(BlockArg("q", (B, Hq, Lq, D), lay["in_specs"][0]),
                BlockArg("k", (B, Hkv, Lkv, D), lay["in_specs"][1]),
                BlockArg("v", (B, Hkv, Lkv, D), lay["in_specs"][2])),
        outputs=(BlockArg("out", lay["out_shapes"][0],
                          lay["out_specs"]),),
        # the kv sweep (grid dim 3, innermost) revisits the output block
        # with VMEM scratch accumulators: declared sequential
        sequential={0: frozenset({3})},
    )


def _flash_fuzz(cfg, rng):
    import jax.numpy as jnp

    from . import ops
    B, Hq, Hkv = cfg["B"], cfg["Hq"], cfg["Hkv"]
    Lq, Lkv, D = cfg["Lq"], cfg["Lkv"], cfg["D"]
    q = jnp.asarray(rng.standard_normal((B, Hq, Lq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Lkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Lkv, D)), jnp.float32)
    kw = dict(causal=cfg.get("causal", True), window=cfg.get("window"),
              softcap=cfg.get("softcap"))
    got = ops.flash_attention(q, k, v, block_q=cfg["block_q"],
                              block_k=cfg["block_k"], interpret=True,
                              **kw)
    want = _jit_oracles()["attention"](q, k, v, **kw)
    # online softmax vs materialized softmax: f32 accumulation noise
    return [("out", got, want, "fp-tolerant")]


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

KERNEL_ENTRIES = [
    {
        "name": "kernels.softthresh.fused_prox_stats",
        "path": "src/repro/kernels/softthresh.py",
        "oracle": "fused_prox_stats",
        "tolerance": "bit-exact",
        "rtol": 1e-11,
        "atol": 1e-11,
        "f64_contract": True,
        "configs": (
            {"label": "aligned", "m": 32, "n": 32, "block": (16, 16)},
            {"label": "edge-tile", "m": 40, "n": 24, "block": (16, 16)},
            {"label": "prime-p", "m": 13, "n": 13, "block": (8, 8)},
            {"label": "weighted-inf-alpha0", "m": 24, "n": 24,
             "block": (16, 16), "weighted": True, "alpha": 0.0},
        ),
        "layout": _softthresh_layout,
        "fuzz": _softthresh_fuzz,
        "trace": _softthresh_trace,
    },
    {
        "name": "kernels.pathstep.fused_path_step",
        "path": "src/repro/kernels/pathstep.py",
        "oracle": "fused_path_step",
        "tolerance": "bit-exact",
        "rtol": 1e-11,
        "atol": 1e-11,
        "f64_contract": True,
        "configs": (
            {"label": "aligned", "c": 2, "p": 16, "block": 8},
            {"label": "prime-p-full-tile", "c": 2, "p": 13, "block": 8},
            {"label": "odd-divisor-edge", "c": 1, "p": 12, "block": 8},
            {"label": "weighted-inf-alpha0", "c": 2, "p": 8, "block": 4,
             "weighted": True, "zero_lam1_lane": True},
        ),
        "layout": _pathstep_layout,
        "fuzz": _pathstep_fuzz,
        "trace": _pathstep_trace,
    },
    {
        "name": "kernels.blocksparse_matmul.blocksparse_matmul",
        "path": "src/repro/kernels/blocksparse_matmul.py",
        "oracle": "blocksparse_matmul",
        "tolerance": "fp-tolerant",
        "rtol": 1e-10,
        "atol": 1e-10,
        "f64_contract": True,
        "configs": (
            {"label": "dense", "p": 16, "bs": 8, "m": 16, "block_n": 8,
             "density": 1.0, "seed": 1},
            {"label": "partial", "p": 32, "bs": 8, "m": 16, "block_n": 8,
             "density": 0.4, "seed": 2},
            {"label": "empty-rows", "p": 16, "bs": 4, "m": 8,
             "block_n": 8, "density": 0.0, "seed": 3},
            {"label": "edge-n", "p": 16, "bs": 8, "m": 12, "block_n": 8,
             "density": 0.7, "seed": 4},
        ),
        "layout": _blocksparse_layout,
        "fuzz": _blocksparse_fuzz,
        "trace": _blocksparse_trace,
    },
    {
        "name": "kernels.flash_attention.flash_attention",
        "path": "src/repro/kernels/flash_attention.py",
        "oracle": "attention",
        "tolerance": "fp-tolerant",
        "rtol": 2e-3,
        "atol": 2e-3,
        # the attention kernel's f32 accumulator is its own contract
        # (mirrors the CA104 flash exemption): CA404 does not apply
        "f64_contract": False,
        "configs": (
            {"label": "causal-gqa", "B": 1, "Hq": 2, "Hkv": 1, "Lq": 32,
             "Lkv": 32, "D": 16, "block_q": 16, "block_k": 16,
             "causal": True},
            {"label": "window-softcap-edge", "B": 1, "Hq": 2, "Hkv": 2,
             "Lq": 40, "Lkv": 40, "D": 16, "block_q": 16, "block_k": 16,
             "causal": False, "window": 16, "softcap": 10.0},
            {"label": "decode-tail", "B": 1, "Hq": 2, "Hkv": 1, "Lq": 8,
             "Lkv": 40, "D": 16, "block_q": 8, "block_k": 16,
             "causal": True},
        ),
        "layout": _flash_layout,
        "fuzz": _flash_fuzz,
    },
]
