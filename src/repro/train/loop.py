"""The production training loop: data -> step -> metrics, with
checkpoint/restart, preemption handling, heartbeats and straggler
monitoring wired in.  Used by launch/train.py and the examples.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from ..models import lm, transformer as T
from ..models.config import ModelConfig
from . import checkpoint as ckpt
from .data import make_source
from .fault import Heartbeat, PreemptionGuard, StragglerMonitor
from .optim import AdamW, cosine_schedule


@dataclass
class TrainerConfig:
    seq_len: int = 512
    global_batch: int = 8
    n_micro: int = 1
    steps: int = 100
    peak_lr: float = 3e-4
    warmup: int = 10
    ckpt_dir: str = ""
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    heartbeat_path: str = ""


@dataclass
class TrainerResult:
    losses: list = field(default_factory=list)
    final_step: int = 0
    preempted: bool = False
    straggler_flags: int = 0


def train(cfg: ModelConfig, tc: TrainerConfig, *, mesh=None,
          state=None, log=print) -> TrainerResult:
    """Run (or resume) a training job. Pass a mesh for distributed runs;
    shardings are derived from the config's logical rules."""
    opt = AdamW(weight_decay=0.1, clip_norm=1.0)
    sched = cosine_schedule(tc.peak_lr, tc.warmup, tc.steps)
    step_fn = lm.make_train_step(cfg, opt, sched, n_micro=tc.n_micro)
    source = make_source(cfg, tc.seq_len, tc.global_batch, tc.seed)

    start_step = 0
    if state is None:
        if tc.ckpt_dir and ckpt.latest_step(tc.ckpt_dir) is not None:
            template = _abstract_state(cfg, opt, tc)
            shardings = (_state_shardings(cfg, opt, mesh, tc)
                         if mesh is not None else None)
            state, manifest = ckpt.restore(tc.ckpt_dir, template,
                                           shardings=shardings)
            start_step = manifest["step"]
            log(f"[train] resumed from step {start_step}")
        else:
            params = T.init_params(cfg, jax.random.PRNGKey(tc.seed),
                                   max_len=tc.seq_len)
            state = lm.TrainState(params, opt.init(params),
                                  jnp.zeros((), jnp.int32))

    if mesh is not None:
        from ..comm.compat import use_mesh
        with use_mesh(mesh):
            return _run(cfg, tc, step_fn, source, state, start_step, log)
    return _run(cfg, tc, step_fn, source, state, start_step, log)


def _run(cfg, tc, step_fn, source, state, start_step, log):
    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    guard = PreemptionGuard().install()
    hb = Heartbeat(tc.heartbeat_path) if tc.heartbeat_path else None
    mon = StragglerMonitor()
    res = TrainerResult()

    step = start_step
    try:
        while step < tc.steps:
            t0 = time.time()
            batch = source(step)
            state, metrics = jit_step(state, batch)
            loss = float(metrics["loss"])
            res.losses.append(loss)
            dt = time.time() - t0
            if mon.record(dt):
                res.straggler_flags += 1
                log(f"[straggler] step {step} took {dt:.2f}s "
                    f"(ewma {mon.ewma:.2f}s)")
            if hb:
                hb.beat(step, {"loss": loss})
            step += 1
            if tc.log_every and step % tc.log_every == 0:
                log(f"[train] step {step} loss {loss:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s")
            stop_now = guard.should_stop
            if tc.ckpt_dir and (step % tc.ckpt_every == 0 or
                                step == tc.steps or stop_now):
                ckpt.save(tc.ckpt_dir, step, state, data_cursor=step)
            if stop_now:
                log(f"[train] preempted at step {step}; checkpointed")
                res.preempted = True
                break
    finally:
        guard.uninstall()
    res.final_step = step
    return res


def _abstract_state(cfg, opt, tc):
    params = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0),
                              max_len=tc.seq_len))
    opt_s = jax.eval_shape(opt.init, params)
    return lm.TrainState(params, opt_s,
                         jax.ShapeDtypeStruct((), jnp.int32))


def _state_shardings(cfg, opt, mesh, tc):
    from jax.sharding import NamedSharding, PartitionSpec as P
    ps = lm.param_shardings(cfg, mesh, max_len=tc.seq_len)
    os_ = lm.opt_shardings(cfg, mesh, opt, max_len=tc.seq_len)
    return lm.TrainState(ps, os_, NamedSharding(mesh, P()))
