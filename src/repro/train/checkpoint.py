"""Sharded, mesh-agnostic checkpoint/restore with elastic resharding.

Format: one directory per step containing
  * ``manifest.json``  — step, flat key list, shapes/dtypes, mesh shape,
    PartitionSpecs at save time, data-pipeline cursor.
  * ``<flatkey>.npy``  — one file per leaf (full logical array, assembled
    from shards on save).

Atomicity: writes go to ``<dir>.tmp`` then a single ``os.rename`` —
a crash mid-save never corrupts the previous checkpoint.  Restore
re-shards every leaf to the CURRENT mesh (device_put with the new
sharding), so a run can resume on a different topology (elastic scaling):
the manifest's specs are advisory, not binding.

At real scale one would write per-shard files + a distributed commit
protocol; the logical format here is deliberately mesh-agnostic so that
upgrade is an IO change, not a format change.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


SEP = "//"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{SEP}"))
    elif hasattr(tree, "_fields"):          # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}{SEP}"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip(SEP[0]).rstrip(SEP[0])] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat, f"{prefix}{k}{SEP}")
                for k in template}
    if hasattr(template, "_fields"):
        return type(template)(*[
            _unflatten_into(getattr(template, k), flat, f"{prefix}{k}{SEP}")
            for k in template._fields])
    if template is None:
        return None
    return flat[prefix.rstrip(SEP[0]).rstrip(SEP[0])]


def save(ckpt_dir: str, step: int, state, *, data_cursor: int = 0,
         mesh=None, keep: int = 3):
    """Atomically write ``state`` (any dict/NamedTuple pytree)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    manifest = {"step": step, "data_cursor": data_cursor,
                "mesh_shape": dict(mesh.shape) if mesh is not None else None,
                "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace(SEP, "__") + ".npy"
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":  # npy has no bf16: store the bits
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                   "dtype": dtype_name}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _gc(ckpt_dir, keep)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template, *, step: int | None = None,
            shardings=None):
    """Load into the structure of ``template``; reshard to ``shardings``
    (a matching pytree of NamedSharding) if given — this is the elastic
    path: the saved mesh shape is irrelevant."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    flat = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(os.path.join(path, info["file"]))
        if info["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if key in flat_shard and flat_shard[key] is not None:
            flat[key] = jax.device_put(arr, flat_shard[key])
        else:
            flat[key] = jax.numpy.asarray(arr)
    state = _unflatten_into(template, flat)
    return state, manifest


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
