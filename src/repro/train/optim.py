"""In-repo optimizers (no optax): AdamW + SGD-momentum, LR schedules,
global-norm clipping, and microbatch gradient accumulation.

The optimizer state is a plain pytree (same structure as params), so the
checkpoint layer and the sharding rules apply to it unchanged — m/v get
the same PartitionSpecs as their parameters (ZeRO-style: optimizer state
is sharded exactly as far as FSDP shards the weights).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


class AdamW(NamedTuple):
    lr: float | None = None          # None -> caller passes lr per step
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
        return AdamWState(jnp.zeros((), jnp.int32), z,
                          jax.tree.map(jnp.copy, z))

    def update(self, grads, state: AdamWState, params, lr=None):
        lr = lr if lr is not None else self.lr
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) *
                         g.astype(jnp.float32), state.m, grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state.v, grads)
        mhat_scale = 1.0 / (1 - b1 ** t)
        vhat_scale = 1.0 / (1 - b2 ** t)

        def upd(p, m_, v_):
            u = (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v), gnorm


class SGDM(NamedTuple):
    lr: float | None = None
    momentum: float = 0.9
    clip_norm: float = 1.0

    def init(self, params):
        return AdamWState(
            jnp.zeros((), jnp.int32),
            jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            {})

    def update(self, grads, state, params, lr=None):
        lr = lr if lr is not None else self.lr
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        m = jax.tree.map(lambda m_, g: self.momentum * m_ +
                         g.astype(jnp.float32), state.m, grads)
        new_params = jax.tree.map(
            lambda p, m_: (p.astype(jnp.float32) - lr * m_).astype(p.dtype),
            params, m)
        return new_params, AdamWState(state.step + 1, m, {}), gnorm


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale
                                   ).astype(l.dtype), tree), g


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------

def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def linear_schedule(peak_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        dec = peak_lr * jnp.clip((total - step) / max(total - warmup, 1),
                                 0.0, 1.0)
        return jnp.where(step < warmup, warm, dec)
    return lr


# ---------------------------------------------------------------------------
# microbatch accumulation
# ---------------------------------------------------------------------------

def accumulate_gradients(loss_fn, params, batch, n_micro: int):
    """Split the leading batch dim into n_micro chunks and average grads
    with a lax.scan (memory-bounded; the standard large-batch trick)."""
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    split = jax.tree.map(
        lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
        batch)

    def body(acc, micro):
        (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, micro)
        acc_l, acc_g = acc
        return (acc_l + l / n_micro,
                jax.tree.map(lambda a, b: a + b / n_micro, acc_g, g)), aux

    zero_g = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    (loss, grads), auxs = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zero_g), split)
    aux = jax.tree.map(lambda a: a[-1], auxs)
    return (loss, aux), grads
