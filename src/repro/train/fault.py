"""Fault-tolerance machinery: preemption handling, heartbeats, straggler
detection, and bounded retry — the pieces that make the training loop
survivable on a 1000+-node cluster.

 * ``PreemptionGuard`` — SIGTERM/SIGINT handler that flips a flag; the
   training loop polls it and checkpoints-then-exits cleanly (the
   standard cloud-TPU maintenance-event protocol).
 * ``Heartbeat`` — writes ``{step, time}`` to a file every step; an
   external watchdog restarts workers whose heartbeat goes stale, and the
   deterministic data pipeline (train/data.py) makes the restart
   bit-exact from the last checkpoint.
 * ``StragglerMonitor`` — EWMA of step time; flags hosts whose steps are
   > ``threshold`` x the fleet median. On a real multi-host run the
   flagged host is reported through the heartbeat file for the scheduler
   to replace; elasticity is handled by checkpoint resharding.
 * ``retry`` — bounded-retry wrapper for transient IO / collective
   failures.
"""
from __future__ import annotations

import json
import os
import signal
import time
from dataclasses import dataclass, field


class PreemptionGuard:
    """Installs signal handlers; ``should_stop`` polled by the loop."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._flag = False
        self._prev = {}
        self._signals = signals

    def install(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        self._flag = True

    @property
    def should_stop(self) -> bool:
        return self._flag

    def uninstall(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


@dataclass
class Heartbeat:
    path: str
    host_id: int = 0

    def beat(self, step: int, extra: dict | None = None):
        rec = {"host": self.host_id, "step": step, "time": time.time()}
        if extra:
            rec.update(extra)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, self.path)

    def read(self) -> dict | None:
        try:
            with open(self.path) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def is_stale(self, timeout_s: float) -> bool:
        rec = self.read()
        return rec is None or (time.time() - rec["time"]) > timeout_s


@dataclass
class StragglerMonitor:
    """EWMA step-time tracker with a relative slowness threshold."""
    alpha: float = 0.1
    threshold: float = 2.0
    ewma: float | None = None
    history: list = field(default_factory=list)

    def record(self, step_time: float) -> bool:
        """Returns True when this step looks straggler-slow."""
        self.history.append(step_time)
        if self.ewma is None:
            self.ewma = step_time
            return False
        slow = step_time > self.threshold * self.ewma
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        return slow

    @property
    def median(self) -> float:
        h = sorted(self.history)
        return h[len(h) // 2] if h else 0.0


def retry(fn, *args, attempts: int = 3, backoff_s: float = 0.5,
          exceptions=(OSError, IOError), **kw):
    """Bounded retry with exponential backoff for transient failures."""
    for i in range(attempts):
        try:
            return fn(*args, **kw)
        except exceptions:
            if i == attempts - 1:
                raise
            time.sleep(backoff_s * (2 ** i))
