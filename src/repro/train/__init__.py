"""Training substrate: optimizers, data pipeline, checkpointing, fault
tolerance, and the production loop.

Only ``optim`` is imported eagerly (models.lm depends on it); import
``repro.train.data`` / ``.loop`` / ``.checkpoint`` / ``.fault`` directly.
"""
from . import optim  # noqa: F401
