"""Deterministic, resumable synthetic data pipeline.

The pipeline is a stateless pure function ``step -> batch`` (threefry
counter-mode RNG keyed on (seed, step)), which is the straggler/elastic
story: a replaced or restarted worker reproduces exactly the batch for
the step it joins at, with NO coordination and no skipped/duplicated
samples.  The checkpoint only needs to record ``step``.

Two sources:
  * ``SyntheticLM``  — token streams with a Zipf-ish marginal + a
    low-order Markov structure so the loss actually decreases.
  * ``SyntheticFrames`` — stub audio/vision frame embeddings (whisper /
    chameleon frontends are stubs per the assignment).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm import Batch


@dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_order: int = 1

    def batch_at(self, step: int) -> Batch:
        """Pure function of step (host-side numpy for the input pipeline;
        devices only see the resulting arrays)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, int(step)]))
        B, L, V = self.global_batch, self.seq_len, self.vocab
        # Zipf marginal over a smallish head + markov next-token bias
        head = min(V, 1024)
        ranks = np.arange(1, head + 1)
        pz = 1.0 / ranks
        pz /= pz.sum()
        base = rng.choice(head, size=(B, L), p=pz).astype(np.int32)
        # markov: with prob .5 next token = f(prev) (learnable structure)
        shift = (base[:, :-1] * 31 + 7) % V
        coin = rng.random((B, L - 1)) < 0.5
        tokens = base.copy()
        tokens[:, 1:] = np.where(coin, shift % V, base[:, 1:])
        targets = np.roll(tokens, -1, axis=1)
        targets[:, -1] = 0
        return Batch(tokens=jnp.asarray(tokens),
                     targets=jnp.asarray(targets), frames=None)

    def jax_batch_at(self, step) -> Batch:
        """Device-side variant (traceable): same structure, threefry keys.
        Used when the input pipeline itself must live inside jit."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        B, L, V = self.global_batch, self.seq_len, self.vocab
        k1, k2 = jax.random.split(key)
        base = jax.random.categorical(
            k1, jnp.log(1.0 / jnp.arange(1, min(V, 1024) + 1)),
            shape=(B, L)).astype(jnp.int32)
        shift = (base * 31 + 7) % V
        coin = jax.random.bernoulli(k2, 0.5, (B, L))
        tokens = jnp.where(coin, shift, base)
        targets = jnp.roll(tokens, -1, axis=1).at[:, -1].set(0)
        return Batch(tokens=tokens, targets=targets, frames=None)


@dataclass(frozen=True)
class SyntheticFrames:
    """Stub modality frontend: precomputed frame/patch embeddings."""
    enc_len: int
    d_model: int
    global_batch: int
    seed: int = 0

    def frames_at(self, step: int, dtype=jnp.bfloat16):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed + 77, int(step)]))
        f = rng.standard_normal(
            (self.global_batch, self.enc_len, self.d_model)) * 0.1
        return jnp.asarray(f, dtype)


def make_source(cfg, seq_len: int, global_batch: int, seed: int = 0):
    lm_src = SyntheticLM(cfg.vocab, seq_len, global_batch, seed)
    if cfg.enc_dec:
        fr_src = SyntheticFrames(cfg.enc_len, cfg.d_model, global_batch, seed)

        def batch_at(step):
            b = lm_src.batch_at(step)
            return Batch(tokens=b.tokens, targets=b.targets,
                         frames=fr_src.frames_at(step, jnp.dtype(cfg.dtype)))
        return batch_at
    return lm_src.batch_at
