"""Paper Figure 4: scaling vs problem size and node count (+ BigQUIC-class
baseline comparison).

  * measured — strong scaling of the distributed Obs/Cov solvers across
    virtual-device counts (subprocess per device count);
  * baseline — our in-repo Gaussian-likelihood proximal baseline (glasso
    objective; BigQUIC stand-in) timed on the same problems;
  * modeled — cost-model projection to 256/1024 nodes at p up to 1.28M
    (the paper's headline 17-minute configuration).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.core.costmodel import EDISON, ProblemShape, tune

from .common import emit, timeit

_CHILD = r"""
import json, time
import numpy as np, jax, jax.numpy as jnp
from repro.core import graphs
from repro.core.distributed import fit_obs
from repro.comm.grid import Grid1p5D
P = %d
prob = graphs.make_problem("chain", p=96, n=48, seed=0)
g = Grid1p5D(P, 1, min(2, P))
r = fit_obs(jnp.asarray(prob.x), 0.2, 0.05, grid=g, tol=1e-5, max_iters=40)
jax.block_until_ready(r.omega)
t0 = time.perf_counter()
r = fit_obs(jnp.asarray(prob.x), 0.2, 0.05, grid=g, tol=1e-5, max_iters=40)
jax.block_until_ready(r.omega)
print("JSON" + json.dumps({"P": P, "t_s": round(time.perf_counter()-t0, 4),
                           "iters": int(r.iters)}))
"""


def _glasso_baseline(p=96, n=48):
    """BigQUIC-class baseline: l1-penalized GAUSSIAN likelihood by
    proximal gradient (same outer loop class, the paper's comparison
    target family)."""
    import jax
    import jax.numpy as jnp
    from repro.core import graphs
    from repro.core.objective import prox_l1_offdiag

    prob = graphs.make_problem("chain", p=p, n=n, seed=0)
    s = jnp.asarray(prob.s) + 0.1 * jnp.eye(p)

    @jax.jit
    def fit():
        def body(carry, _):
            omega, tau = carry
            grad = s - jnp.linalg.inv(omega)
            cand = prox_l1_offdiag(omega - tau * grad, tau * 0.2)
            # crude PD safeguard
            ok = jnp.all(jnp.linalg.eigvalsh(cand) > 1e-4)
            omega = jnp.where(ok, cand, omega)
            return (omega, tau), None
        init = (jnp.eye(p), jnp.asarray(0.1))
        (omega, _), _ = jax.lax.scan(body, init, None, length=40)
        return omega

    return timeit(fit, repeats=2)


def run():
    rows = []
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    for P in [1, 2, 4, 8, 16]:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run([sys.executable, "-c", _CHILD % P], env=env,
                              capture_output=True, text=True, timeout=560)
        for line in proc.stdout.splitlines():
            if line.startswith("JSON"):
                rows.append(json.loads(line[4:]))
    emit("fig4_scaling_measured", rows)

    t_base, _ = _glasso_baseline()
    print(f"# glasso-class baseline (p=96): {t_base:.3f}s vs "
          f"hp-concord 1-dev {rows[0]['t_s'] if rows else '?'}s")

    # modeled projection at paper scale
    mrows = []
    for p, nodes in [(40000, 1), (40000, 16), (80000, 1024),
                     (320000, 256), (1280000, 1024)]:
        P = nodes * 2  # paper: 2 MPI ranks/node
        shape = ProblemShape(p=p, n=100, d=4.0, s=40, t=10.0)
        try:
            best = tune(shape, P, EDISON, variants=("obs",))
            mrows.append({"p": p, "nodes": nodes,
                          "model_t_s": round(best.total, 1),
                          "c_x": best.c_x, "c_omega": best.c_omega})
        except ValueError as e:
            mrows.append({"p": p, "nodes": nodes, "model_t_s": -1,
                          "c_x": 0, "c_omega": 0})
    emit("fig4_scaling_model", mrows)
    return rows
