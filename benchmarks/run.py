"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig2,table1]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = ["fig2_crossover", "fig3_replication", "fig4_scaling",
           "table1_recovery", "path_warmstart", "path_batch",
           "gram_stream", "kernel_bench", "sparse_crossover",
           "lm_roofline"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else MODULES
    failures = []
    for name in names:
        full = [m for m in MODULES if m.startswith(name)]
        for mod_name in full or [name]:
            print(f"\n==== benchmarks.{mod_name} ====")
            t0 = time.time()
            try:
                mod = __import__(f"benchmarks.{mod_name}",
                                 fromlist=["run"])
                mod.run()
                print(f"# {mod_name} done in {time.time()-t0:.1f}s")
            except Exception:
                traceback.print_exc()
                failures.append(mod_name)
    if failures:
        print(f"\nFAILED: {failures}", file=sys.stderr)
        sys.exit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
