"""Paper Figure 3: the (c_X, c_Omega) replication heatmap.

Two layers of evidence:
  * measured — the distributed Obs solver on 16 virtual devices across
    every feasible (c_X, c_Omega) pair (subprocess so the device count
    does not leak into other benchmarks);
  * modeled — Lemma 3.4/3.5 communication volumes at the paper's scale
    (512 processes, p=40k, n=100), reproducing the 5x-speedup structure.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.core.costmodel import EDISON, ProblemShape, obs_costs

from .common import emit

_CHILD = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from repro.core import graphs
from repro.estimator import ConcordEstimator, SolverConfig
prob = graphs.make_problem("chain", p=64, n=32, seed=0)
x = jnp.asarray(prob.x)
out = []
P = 16
c = 1
cands = []
while c <= P:
    cands.append(c); c *= 2
for cx in cands:
    for co in cands:
        if cx * co > P or P % (cx * co):
            continue
        est = ConcordEstimator(
            lam1=0.2, lam2=0.05,
            config=SolverConfig(backend="distributed", variant="obs",
                                c_x=cx, c_omega=co, tol=1e-5, max_iters=60))
        est.fit(x)                       # warm-up (compile)
        rep = est.fit(x).report_         # measure
        out.append({"c_x": cx, "c_omega": co,
                    "t_s": round(rep.wall_time_s, 4),
                    "iters": rep.iters})
print("JSON" + json.dumps(out))
"""


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=560)
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("JSON"):
            rows = json.loads(line[4:])
    if proc.returncode != 0 or not rows:
        print(proc.stderr[-2000:], file=sys.stderr)
        rows = [{"c_x": 0, "c_omega": 0, "t_s": -1, "iters": 0,
                 "error": "subprocess failed"}]
    emit("fig3_replication_measured", rows)

    # modeled heatmap at paper scale (512 procs, p=40k, n=100)
    shape = ProblemShape(p=40000, n=100, d=4.0, s=30, t=10.0)
    mrows = []
    P = 512
    c = 1
    cands = []
    while c <= P:
        cands.append(c)
        c *= 2
    for cx in cands:
        for co in cands:
            if cx * co > P:
                continue
            cb = obs_costs(shape, P, cx, co, EDISON)
            mrows.append({"c_x": cx, "c_omega": co,
                          "model_t_s": round(cb.total, 3),
                          "words": int(cb.words)})
    best = min(mrows, key=lambda r: r["model_t_s"])
    base = [r for r in mrows if r["c_x"] == 1 and r["c_omega"] == 1][0]
    print(f"# modeled replication speedup at paper scale: "
          f"{base['model_t_s'] / best['model_t_s']:.1f}x "
          f"(best c_x={best['c_x']}, c_omega={best['c_omega']})")
    emit("fig3_replication_model", mrows)
    return rows
