"""Penalty-family recovery sweep (BENCH_penalty_sweep.json).

For each scenario family (banded / hub / scale_free — the PR-4 generator
suite) the same streamed-Gram problem is fit with three penalties through
the composable penalty API (``core.penalty``):

  * ``l1``        — the paper's penalty (baseline);
  * ``adaptive``  — the two-stage adaptive lasso
                    (``fit_path(adaptive=True)``: l1 stage-1 path,
                    weights 1/(|omega_hat|+eps), weighted stage-2 path);
  * ``scad``      — SCAD(3.7), the nonconvex unbiased-tail penalty.

Each penalty's path is scanned with the paper's equal-sparsity protocol
(pick the lam1 whose estimate matches the true average degree), and PPV /
FDR against the known generator graph are reported per (family, penalty)
cell, plus iteration counts and wall time.  Emits
results/BENCH_penalty_sweep.csv and results/BENCH_penalty_sweep.json —
the JSON is uploaded as a CI artifact to track recovery quality of the
penalty layer across commits.

  PYTHONPATH=src python -m benchmarks.penalty_sweep [--quick]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core import graphs
from repro.estimator import ConcordEstimator, SolverConfig

from .common import emit, write_bench

FAMILIES = ("banded", "hub", "scale_free")
PENALTIES = ("l1", "adaptive", "scad:3.7")


def _degree_matched(path, target_deg):
    """The path point whose estimate matches the true average degree (the
    paper's equal-sparsity protocol), plus that estimate's degree."""
    best = None
    for rep in path:
        deg = graphs.avg_degree(np.asarray(rep.omega))
        gap = abs(deg - target_deg)
        if best is None or gap < best[0]:
            best = (gap, rep, deg)
    return best[1], best[2]


def _fit_cell(s, n, penalty: str, grid, config) -> tuple:
    """(PathResult, wall seconds) for one (problem, penalty) cell."""
    t0 = time.perf_counter()
    if penalty == "adaptive":
        est = ConcordEstimator(lam2=0.02, config=config)
        path = est.fit_path(s=jnp.asarray(s), n_samples=n, lam1_grid=grid,
                            adaptive=True, score_bic=True)
    else:
        est = ConcordEstimator(lam1=float(grid[0]), lam2=0.02,
                               penalty=penalty, config=config)
        path = est.fit_path(s=jnp.asarray(s), n_samples=n, lam1_grid=grid,
                            score_bic=True)
    return path, time.perf_counter() - t0


def run(p: int = 64, n: int = 400, n_lams: int = 8, cond: float = 10.0):
    from repro.data import compute_gram, make_scenario

    config = SolverConfig(backend="reference", variant="cov",
                          tol=1e-5, max_iters=250)
    grid = np.linspace(0.05, 0.6, n_lams)
    rows = []
    for family in FAMILIES:
        sc = make_scenario(family, p, cond=cond, seed=0)
        g = compute_gram(sc.source(n, chunk_rows=max(64, n // 8), seed=1),
                         transform="standardize")
        for penalty in PENALTIES:
            path, wall = _fit_cell(g.s, g.n, penalty, grid, config)
            rep, deg = _degree_matched(path, sc.avg_degree)
            ppv, fdr = graphs.ppv_fdr(np.asarray(rep.omega), sc.omega)
            rows.append({
                "family": family, "penalty": penalty,
                "p": p, "n": n,
                "lam1": round(float(rep.lam1), 3),
                "ppv_pct": round(100 * ppv, 2),
                "fdr_pct": round(100 * fdr, 2),
                "avg_degree": round(deg, 2),
                "true_degree": round(sc.avg_degree, 2),
                "path_iters": int(path.total_iters),
                "wall_s": round(wall, 3),
                "report_penalty": rep.penalty,
            })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller problems + coarser lam1 grid (CI)")
    ap.add_argument("--p", type=int, default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--n-lams", type=int, default=None)
    args = ap.parse_args(argv)

    p = args.p or (48 if args.quick else 64)
    n = args.n or (300 if args.quick else 400)
    n_lams = args.n_lams or (5 if args.quick else 8)

    rows = run(p=p, n=n, n_lams=n_lams)
    emit("BENCH_penalty_sweep", rows)

    by_family = {}
    for r in rows:
        by_family.setdefault(r["family"], {})[r["penalty"]] = {
            "ppv_pct": r["ppv_pct"], "fdr_pct": r["fdr_pct"],
            "lam1": r["lam1"], "wall_s": r["wall_s"],
        }
    summary = {
        "p": p, "n": n, "n_lams": n_lams,
        "families": by_family,
        "rows": rows,
    }
    path = write_bench("BENCH_penalty_sweep", summary)
    for fam, cells in by_family.items():
        line = "  ".join(f"{pen}: PPV {c['ppv_pct']:.0f}% FDR "
                         f"{c['fdr_pct']:.0f}%" for pen, c in cells.items())
        print(f"# {fam}: {line}")
    print(f"# -> {path}")
    return rows


if __name__ == "__main__":
    main()
