"""Paper Table 1: iterations-to-converge + PPV/FDR support recovery on
chain and random graphs (CPU-sized p; same protocol as the paper —
tuning chosen so the estimate matches the true average degree)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import graphs
from repro.estimator import ConcordEstimator, SolverConfig

from .common import emit

_CONFIG = SolverConfig(backend="reference", variant="cov",
                       tol=1e-5, max_iters=250)


def _fit_at_degree(prob, target_deg, lam2=0.02, n_lams=8):
    """Scan lam1 until the estimate's average degree matches the truth
    (the paper's equal-sparsity protocol) — one warm-started path call."""
    path = ConcordEstimator(lam2=lam2, config=_CONFIG).fit_path(
        s=jnp.asarray(prob.s), n_samples=prob.x.shape[0],
        lam1_grid=np.linspace(0.05, 0.6, n_lams), score_bic=False)
    best = None
    for rep in path:
        deg = graphs.avg_degree(np.asarray(rep.omega))
        gap = abs(deg - target_deg)
        if best is None or gap < best[0]:
            best = (gap, rep.lam1, rep, deg)
    return best[1], best[2], best[3]


def run():
    rows = []
    for kind, n_rel, avg_deg in [("chain", None, 2), ("random", 1, 6),
                                 ("random", 2, 6)]:
        for p in [64, 128, 256]:
            n = 100 if n_rel is None else p * 2 // n_rel
            prob = graphs.make_problem(kind, p=p, n=n, seed=0,
                                       avg_degree=avg_deg)
            lam1, r, deg = _fit_at_degree(prob, avg_deg)
            ppv, fdr = graphs.ppv_fdr(np.asarray(r.omega), prob.omega0)
            rows.append({
                "graph": kind, "p": p, "n": n,
                "lam1": round(float(lam1), 3),
                "iters": int(r.iters),
                "ls_total": int(r.ls_total),
                "ppv_pct": round(100 * ppv, 2),
                "fdr_pct": round(100 * fdr, 2),
                "avg_degree": round(deg, 2),
            })
    emit("table1_recovery", rows)
    return rows
