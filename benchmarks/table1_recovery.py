"""Paper Table 1: iterations-to-converge + PPV/FDR support recovery on
chain and random graphs (CPU-sized p; same protocol as the paper —
tuning chosen so the estimate matches the true average degree) — PLUS a
sweep over the scenario-generator suite (``repro.data.scenarios``): ≥5
graph families, each streamed through the out-of-core Gram pipeline
(seeded chunked sampler -> GramAccumulator -> fit_gram), with per-family
recovery metrics for both the l1 penalty and the two-stage adaptive
lasso (``fit_path(adaptive=True)``, the composable-penalty refit).

Emits results/table1_recovery.csv (all rows) and
results/table1_recovery.json ({"classic": [...], "scenarios": [...]}).
"""
from __future__ import annotations


import numpy as np
import jax.numpy as jnp

from repro.core import graphs
from repro.estimator import ConcordEstimator, SolverConfig

from .common import emit, write_bench

_CONFIG = SolverConfig(backend="reference", variant="cov",
                       tol=1e-5, max_iters=250)

#: scenario-family sweep cells: (family, p, n, cond)
SCENARIO_CELLS = [
    ("banded", 64, 400, 10.0),
    ("hub", 64, 400, 10.0),
    ("erdos_renyi", 64, 400, 10.0),
    ("block", 64, 400, 10.0),
    ("scale_free", 64, 400, 10.0),
]


def _degree_match(path, target_deg):
    """The path point whose estimate matches the true average degree (the
    paper's equal-sparsity protocol): (lam1, report, degree)."""
    best = None
    for rep in path:
        deg = graphs.avg_degree(np.asarray(rep.omega))
        gap = abs(deg - target_deg)
        if best is None or gap < best[0]:
            best = (gap, rep.lam1, rep, deg)
    return best[1], best[2], best[3]


def _fit_at_degree(s, n, target_deg, lam2=0.02, n_lams=8, adaptive=False):
    """Degree-matched fit over a warm-started lam1 path.  ``adaptive``
    runs the two-stage adaptive-lasso refit (the composable-penalty path:
    l1 stage 1, pointwise weighted stage 2) and returns the whole
    PathResult too, so callers can reuse its ``stage1`` as the l1 column
    without re-solving."""
    path = ConcordEstimator(lam2=lam2, config=_CONFIG).fit_path(
        s=jnp.asarray(s), n_samples=n,
        lam1_grid=np.linspace(0.05, 0.6, n_lams), score_bic=False,
        adaptive=adaptive)
    return (*_degree_match(path, target_deg), path)


def _classic_rows():
    rows = []
    for kind, n_rel, avg_deg in [("chain", None, 2), ("random", 1, 6),
                                 ("random", 2, 6)]:
        for p in [64, 128, 256]:
            n = 100 if n_rel is None else p * 2 // n_rel
            prob = graphs.make_problem(kind, p=p, n=n, seed=0,
                                       avg_degree=avg_deg)
            lam1, r, deg, _ = _fit_at_degree(prob.s, prob.x.shape[0], avg_deg)
            ppv, fdr = graphs.ppv_fdr(np.asarray(r.omega), prob.omega0)
            rows.append({
                "graph": kind, "p": p, "n": n,
                "lam1": round(float(lam1), 3),
                "iters": int(r.iters),
                "ls_total": int(r.ls_total),
                "ppv_pct": round(100 * ppv, 2),
                "fdr_pct": round(100 * fdr, 2),
                "avg_degree": round(deg, 2),
            })
    return rows


def _scenario_rows():
    """Per-family recovery through the FULL streaming path: the sampler
    never materializes X; the Gram is accumulated chunk-at-a-time and
    handed to ``fit_gram``.  Each family also gets an ADAPTIVE-lasso
    column — the two-stage ``fit_path(adaptive=True)`` refit run through
    the same streaming Gram front end."""
    from repro.data import compute_gram, make_scenario

    rows = []
    for family, p, n, cond in SCENARIO_CELLS:
        sc = make_scenario(family, p, cond=cond, seed=0)
        g = compute_gram(sc.source(n, chunk_rows=max(64, n // 8), seed=1),
                         transform="standardize")
        # ONE adaptive call: its stage-1 l1 path doubles as the l1 column
        lam1_a, r_a, deg_a, apath = _fit_at_degree(g.s, g.n, sc.avg_degree,
                                                   adaptive=True)
        lam1, r, deg = _degree_match(apath.stage1, sc.avg_degree)
        ppv, fdr = graphs.ppv_fdr(np.asarray(r.omega), sc.omega)
        ppv_a, fdr_a = graphs.ppv_fdr(np.asarray(r_a.omega), sc.omega)
        rows.append({
            "graph": family, "p": p, "n": n,
            "cond": round(float(sc.cond), 2),
            "true_degree": round(sc.avg_degree, 2),
            "lam1": round(float(lam1), 3),
            "iters": int(r.iters),
            "ls_total": int(r.ls_total),
            "ppv_pct": round(100 * ppv, 2),
            "fdr_pct": round(100 * fdr, 2),
            "avg_degree": round(deg, 2),
            "lam1_adapt": round(float(lam1_a), 3),
            "ppv_adapt_pct": round(100 * ppv_a, 2),
            "fdr_adapt_pct": round(100 * fdr_a, 2),
            "avg_degree_adapt": round(deg_a, 2),
            "n_chunks": int(g.n_chunks),
            "transform": g.transform,
        })
    return rows


def run():
    classic = _classic_rows()
    scenarios = _scenario_rows()
    emit("table1_recovery", classic + scenarios)
    path = write_bench("table1_recovery",
                       {"classic": classic, "scenarios": scenarios})
    n_fam = len({r["graph"] for r in scenarios})
    print(f"# scenario sweep: {n_fam} families, l1 PPV "
          f"{min(r['ppv_pct'] for r in scenarios):.0f}-"
          f"{max(r['ppv_pct'] for r in scenarios):.0f}%, adaptive PPV "
          f"{min(r['ppv_adapt_pct'] for r in scenarios):.0f}-"
          f"{max(r['ppv_adapt_pct'] for r in scenarios):.0f}% -> {path}")
    return classic + scenarios
