"""Observability overhead gate (BENCH_obs_overhead.json).

Runs the SAME p=512 float64 lam1 path twice on the reference backend —
once at ``obs="off"`` and once at ``obs="summary"`` — and gates the
relative wall-time overhead of the instrumented run below 2%.  The obs
mode is host-side only (spans, counters, the cost-model feed); it is not
a static argument of any jitted program, so both runs reuse the same
compiled solver and the only cost the gate can see is the tracer's own
bookkeeping.  The two paths must also be BIT-EXACT: instrumentation
observes a solve, it never changes one.

Runs are interleaved off/summary per repeat and the gate compares the
best-of-N wall per mode (min filters scheduler noise — the same policy
as the path-batch benchmark), so slow host drift cannot land on one
side of the gate.

Emits results/BENCH_obs_overhead.csv and results/BENCH_obs_overhead.json
(top-level ``overhead_pct`` / ``gate_pct`` / ``passed`` — the CI obs job
uploads the JSON and fails the build when ``passed`` is false).

  PYTHONPATH=src python -m benchmarks.obs_overhead [--quick]

Default: 8-point path at p=512 (the acceptance-criteria shape);
``--quick`` shrinks to p=128 for smoke runs.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from .common import emit, write_bench

#: maximum tolerated wall overhead of obs="summary" vs obs="off" at the
#: acceptance shape (p=512: each path point solves for hundreds of ms,
#: so the tracer's fixed ~0.2ms/point bookkeeping is well under 2%)
GATE_PCT = 2.0

#: smoke-run gate (--quick, p=128): the same fixed per-point cost
#: against millisecond solves — a sanity bound, not the acceptance gate
GATE_QUICK_PCT = 25.0


def run(p: int = 512, n: int = 1024, points: int = 8, tol: float = 1e-6,
        max_iters: int = 400, repeats: int = 5,
        gate_pct: float = GATE_PCT):
    import jax
    jax.config.update("jax_enable_x64", True)

    from repro.core import graphs
    from repro.estimator import ConcordEstimator, SolverConfig

    prob = graphs.make_problem("chain", p, n, seed=0)
    grid = np.geomspace(0.4, 0.05, points)

    def run_path(obs: str):
        config = SolverConfig(backend="reference", variant="cov",
                              tol=tol, max_iters=max_iters, obs=obs)
        est = ConcordEstimator(penalty="l1", config=config)
        # cold points (no warm start): each solve runs its full cold
        # iteration count, so the timed region is seconds of solver work
        # against which the tracer's fixed per-point cost is measured —
        # a warm-started path is so fast that host noise swamps the gate
        res = est.fit_path(s=prob.s, lam1_grid=grid, n_samples=n,
                           warm_start=False, score_bic=False)
        jax.block_until_ready(res.reports[-1].omega)
        return res

    # warmup: compile the shared programs AND pay the obs package's lazy
    # first import outside the timed region
    run_path("off")
    run_path("summary")

    walls = {"off": [], "summary": []}
    paths = {}
    for _ in range(repeats):
        for obs in ("off", "summary"):
            t0 = time.perf_counter()
            paths[obs] = run_path(obs)
            walls[obs].append(time.perf_counter() - t0)

    # instrumented solves are bit-exact vs the uninstrumented path
    for i in range(points):
        np.testing.assert_array_equal(
            np.asarray(paths["summary"].reports[i].omega),
            np.asarray(paths["off"].reports[i].omega),
            err_msg=f"obs='summary' changed the solve at path point {i}")

    t_off = float(min(walls["off"]))
    t_summary = float(min(walls["summary"]))
    overhead_pct = 100.0 * (t_summary - t_off) / t_off
    passed = overhead_pct < gate_pct

    rows = [{"obs": obs, "repeat": i, "wall_s": round(w, 4)}
            for obs in ("off", "summary")
            for i, w in enumerate(walls[obs])]
    emit("BENCH_obs_overhead", rows)

    summary = {
        "p": p, "n": n, "points": points, "dtype": "float64",
        "tol": tol, "max_iters": max_iters, "repeats": repeats,
        "backend": "reference",
        "wall_off_s": round(t_off, 4),
        "wall_summary_s": round(t_summary, 4),
        "wall_off_all_s": [round(w, 4) for w in walls["off"]],
        "wall_summary_all_s": [round(w, 4) for w in walls["summary"]],
        "overhead_pct": round(overhead_pct, 3),
        "gate_pct": gate_pct,
        "bitexact": True,
        "passed": passed,
    }
    path = write_bench("BENCH_obs_overhead", summary)
    print(f"# {points}-point f64 path at p={p}: obs=off {t_off:.2f}s, "
          f"obs=summary {t_summary:.2f}s -> overhead "
          f"{overhead_pct:+.2f}% (gate <{gate_pct:g}%) "
          f"{'OK' if passed else 'FAIL'} -> {path}")
    assert passed, (
        f"obs='summary' overhead {overhead_pct:.2f}% exceeds the "
        f"{gate_pct:g}% gate")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shape for smoke runs (p=128, n=320)")
    ap.add_argument("--p", type=int, default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--points", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)
    p = args.p or (128 if args.quick else 512)
    n = args.n or (320 if args.quick else 1024)
    gate = GATE_QUICK_PCT if args.quick else GATE_PCT
    return run(p=p, n=n, points=args.points, repeats=args.repeats,
               gate_pct=gate)


if __name__ == "__main__":
    main()
