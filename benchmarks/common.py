"""Shared benchmark utilities: timing, CSV emission."""
from __future__ import annotations

import csv
import os
import time

OUT_DIR = os.environ.get("BENCH_OUT", "results")


def timeit(fn, *args, repeats: int = 3, warmup: int = 1):
    """Median wall time of fn(*args) (block_until_ready'd)."""
    import jax
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2], r


def emit(name: str, rows: list[dict]):
    """Print a CSV block and save it under results/."""
    if not rows:
        print(f"# {name}: no rows")
        return
    keys = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    print(f"# --- {name} ---")
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys, restval="")
        w.writeheader()
        w.writerows(rows)
