"""Shared benchmark utilities: timing, CSV emission, BENCH JSON envelope."""
from __future__ import annotations

import csv
import json
import os
import platform
import subprocess
import time

OUT_DIR = os.environ.get("BENCH_OUT", "results")

#: version of the shared BENCH_*.json envelope written by write_bench
BENCH_SCHEMA = 1


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def bench_meta() -> dict:
    """The shared provenance envelope stamped on every BENCH artifact:
    schema version, host fingerprint, jax version, x64 flag, git rev.
    Lets downstream tooling reject cross-host or cross-version
    comparisons instead of silently mixing them."""
    import jax

    return {
        "bench_schema": BENCH_SCHEMA,
        "host": platform.node(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count(),
        "python": platform.python_version(),
        "jax_version": jax.__version__,
        "jax_backend": jax.default_backend(),
        "x64": bool(jax.config.jax_enable_x64),
        "git_rev": _git_rev(),
    }


def write_bench(name: str, payload: dict) -> str:
    """Write ``results/<name>.json`` with the payload's keys TOP-LEVEL
    (existing artifact gates read them there) plus the ``meta`` envelope.
    Returns the path written."""
    if "meta" in payload:
        raise ValueError("payload already has a 'meta' key; the envelope "
                         "would clobber it")
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w", encoding="utf-8") as f:
        json.dump({**payload, "meta": bench_meta()}, f, indent=2)
    return path


def timeit(fn, *args, repeats: int = 3, warmup: int = 1):
    """Median wall time of fn(*args) (block_until_ready'd)."""
    import jax
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2], r


def emit(name: str, rows: list[dict]):
    """Print a CSV block and save it under results/."""
    if not rows:
        print(f"# {name}: no rows")
        return
    keys = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    print(f"# --- {name} ---")
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys, restval="")
        w.writeheader()
        w.writerows(rows)
