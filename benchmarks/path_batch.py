"""Batched vs sequential lambda-path benchmark (BENCH_path_batch.json).

Solves the same descending lam1 grid twice in float64:

  * sequential — one cold ``solve_reference`` per path point (the
    apples-to-apples baseline: identical settings, identical solves);
  * batched — the ENTIRE grid as ONE compiled multi-problem program
    through ``core.batch.solve_path_batched`` (vmap'd prox loop, finished
    points frozen by carry masking while stragglers iterate).

Per-point estimates must agree to 1e-5 (float64, where summation-order
noise sits far below line-search decision margins; per project memory f32
fixed points scatter ~1e-4).  Emits results/BENCH_path_batch.csv and
results/BENCH_path_batch.json — the JSON is uploaded as a CI artifact to
track the throughput trajectory of the batched engine.

  PYTHONPATH=src python -m benchmarks.path_batch [--quick]

Default: 8-point path at p=512 (the acceptance-criteria shape);
``--quick`` shrinks to p=128 for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from .common import OUT_DIR, emit

AGREEMENT_ATOL = 1e-5


def run(p: int = 512, n: int = 1024, points: int = 8, tol: float = 1e-6,
        max_iters: int = 300, repeats: int = 2):
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import batch, graphs
    from repro.core.prox import solve_reference

    prob = graphs.make_problem("chain", p, n, seed=0)
    s = jnp.asarray(prob.s, jnp.float64)
    grid = np.geomspace(0.4, 0.08, points)
    lam2 = 0.05
    kw = dict(tol=tol, max_iters=max_iters)

    def run_sequential():
        return [solve_reference(s, float(l1), lam2, variant="cov", **kw)
                for l1 in grid]

    def run_batched():
        res = batch.solve_path_batched(s, jnp.asarray(grid), lam2,
                                       variant="cov", **kw)
        jax.block_until_ready(res.omega)
        return res

    # warmup (compile both programs), then timed repeats
    seq = run_sequential()
    bat = run_batched()
    t_seq, t_bat = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        seq = run_sequential()
        jax.block_until_ready(seq[-1].omega)
        t_seq.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        bat = run_batched()
        t_bat.append(time.perf_counter() - t0)
    t_sequential = float(np.median(t_seq))
    t_batched = float(np.median(t_bat))

    rows, max_err = [], 0.0
    for i, l1 in enumerate(grid):
        err = float(jnp.max(jnp.abs(bat.omega[i] - seq[i].omega)))
        max_err = max(max_err, err)
        rows.append({
            "lam1": round(float(l1), 5),
            "seq_iters": int(seq[i].iters),
            "bat_iters": int(bat.iters[i]),
            "seq_ls": int(seq[i].ls_total),
            "bat_ls": int(bat.ls_total[i]),
            "converged": bool(bat.converged[i]),
            "stalled": bool(bat.stalled[i]),
            "max_abs_err": err,
        })
    emit("BENCH_path_batch", rows)

    agrees = max_err <= AGREEMENT_ATOL
    summary = {
        "p": p, "n": n, "points": points, "dtype": "float64",
        "tol": tol, "max_iters": max_iters,
        "t_sequential_s": round(t_sequential, 4),
        "t_batched_s": round(t_batched, 4),
        "speedup_batched": round(t_sequential / t_batched, 3),
        "agreement_atol": AGREEMENT_ATOL,
        "max_abs_err": max_err,
        "agrees": agrees,
        "points_detail": rows,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_path_batch.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2)
    print(f"# {points}-point f64 path at p={p}: sequential "
          f"{t_sequential:.2f}s, batched {t_batched:.2f}s as one program "
          f"({t_sequential / t_batched:.2f}x); max |dOmega| {max_err:.2e} "
          f"(atol {AGREEMENT_ATOL:g}) -> {path}")
    assert agrees, (
        f"batched path disagrees with the sequential reference: "
        f"max err {max_err:.2e} > {AGREEMENT_ATOL:g}")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shape for CI (p=128, n=320)")
    ap.add_argument("--p", type=int, default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--points", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=2)
    args = ap.parse_args(argv)
    p = args.p or (128 if args.quick else 512)
    n = args.n or (320 if args.quick else 1024)
    return run(p=p, n=n, points=args.points, repeats=args.repeats)


if __name__ == "__main__":
    main()
