"""Batched vs sequential lambda-path benchmark (BENCH_path_batch.json).

Solves the same descending lam1 grid three ways in float64:

  * sequential — one cold ``solve_reference`` per path point, shipped
    defaults (the honest baseline: what a user gets without the batched
    engine);
  * batched/matched — the compact engine at DEFAULT knobs (XLA gemm, no
    pilot, same tau schedule as sequential).  Every lane must be
    BIT-EXACTLY equal to its sequential solve with identical per-lane
    iteration and line-search counts — the refactor-regression gate;
  * batched/tuned — the compact engine at its measured-best CPU config
    (greedy tau schedule, pilot warm start, host BLAS gemm, small waves).
    This is the ``speedup_vs_sequential`` headline.  Its lanes are not
    bit-compatible with cold XLA solves (different gemm, warm starts), so
    its exactness contract is checked against the matched twin instead:
    each lane must be bit-exactly equal (same iters) to a single-lane run
    of the SAME engine from the same omega0 — batching never changes a
    trajectory, only schedules it.

Emits results/BENCH_path_batch.csv and results/BENCH_path_batch.json —
the JSON (with ``speedup_vs_sequential``, the active-lane occupancy
timeline and the segment count) is uploaded as a CI artifact and gated
by the path-batch job (fails below 1.0x).

  PYTHONPATH=src python -m benchmarks.path_batch [--quick]

Default: 8-point path at p=512 (the acceptance-criteria shape);
``--quick`` shrinks to p=128 for CI.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from .common import emit, write_bench

#: tuned-vs-sequential solution agreement (two tol=1e-6 fixed points
#: reached along different trajectories; bit-exactness is asserted
#: against the matched single-lane twin, not against this)
AGREEMENT_ATOL = 1e-4

#: the measured-best compact-engine config on a CPU host (greedy tau,
#: median-lane pilot warm start, host BLAS stepper, cache-sized waves)
TUNED = dict(tau_schedule="greedy", warm_start="pilot", gemm="host",
             max_lanes=2, chunk=8)


def _best_of(fn, repeats: int):
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        ts.append(time.perf_counter() - t0)
    return float(min(ts)), out


def run(p: int = 512, n: int = 1024, points: int = 8, tol: float = 1e-6,
        max_iters: int = 400, repeats: int = 3):
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import batch, graphs
    from repro.core.prox import solve_reference

    prob = graphs.make_problem("chain", p, n, seed=0)
    s = jnp.asarray(prob.s, jnp.float64)
    grid = np.geomspace(0.4, 0.08, points)
    lam2 = 0.05
    kw = dict(variant="cov", tol=tol, max_iters=max_iters)
    tuned = dict(TUNED)
    if jax.default_backend() != "cpu":
        tuned["gemm"] = "xla"   # the host BLAS stepper is CPU-only

    def run_sequential():
        res = [solve_reference(s, float(l1), lam2, **kw) for l1 in grid]
        jax.block_until_ready(res[-1].omega)
        return res

    def run_matched():
        res = batch.solve_path_batched(s, jnp.asarray(grid), lam2, **kw)
        jax.block_until_ready(res.omega)
        return res

    def run_tuned():
        res, stats = batch.solve_path_batched(
            s, jnp.asarray(grid), lam2, **kw, **tuned, return_stats=True)
        jax.block_until_ready(res.omega)
        return res, stats

    # warmup (compile all programs), then timed best-of-N repeats
    run_sequential(), run_matched(), run_tuned()
    t_sequential, seq = _best_of(run_sequential, repeats)
    t_matched, mat = _best_of(run_matched, repeats)
    t_tuned, (tun, stats) = _best_of(run_tuned, repeats)

    # matched contract: bit-exact lanes, identical per-lane telemetry
    for i in range(points):
        np.testing.assert_array_equal(
            np.asarray(mat.omega[i]), np.asarray(seq[i].omega),
            err_msg=f"matched lane {i} is not bit-exact vs sequential")
        assert int(mat.iters[i]) == int(seq[i].iters)
        assert int(mat.ls_total[i]) == int(seq[i].ls_total)

    # tuned contract: every lane bit-exact vs a SINGLE-LANE run of the
    # same engine from the same omega0 (the pilot runs cold; the rest
    # warm-start from the pilot's solution) — batching only schedules
    twin_cfg = {k: v for k, v in tuned.items() if k != "warm_start"}
    pilot = int(stats.pilot_lane)
    om_pilot = tun.omega[pilot] if pilot >= 0 else None
    for i in range(points):
        om0 = None if (pilot < 0 or i == pilot) else om_pilot
        solo = batch.solve_path_batched(
            s, jnp.asarray(grid[i:i + 1]), lam2, omega0=om0, **kw,
            **twin_cfg)
        np.testing.assert_array_equal(
            np.asarray(tun.omega[i]), np.asarray(solo.omega[0]),
            err_msg=f"tuned lane {i} diverged from its single-lane twin")
        assert int(tun.iters[i]) == int(solo.iters[0])
        assert int(tun.ls_total[i]) == int(solo.ls_total[0])

    rows, max_err = [], 0.0
    for i, l1 in enumerate(grid):
        err = float(jnp.max(jnp.abs(tun.omega[i] - seq[i].omega)))
        max_err = max(max_err, err)
        rows.append({
            "lam1": round(float(l1), 5),
            "seq_iters": int(seq[i].iters),
            "matched_iters": int(mat.iters[i]),
            "tuned_iters": int(tun.iters[i]),
            "seq_ls": int(seq[i].ls_total),
            "matched_ls": int(mat.ls_total[i]),
            "tuned_ls": int(tun.ls_total[i]),
            "converged": bool(tun.converged[i]),
            "stalled": bool(tun.stalled[i]),
            "matched_bitexact": True,
            "tuned_max_abs_err": err,
        })
    emit("BENCH_path_batch", rows)

    agrees = max_err <= AGREEMENT_ATOL
    speedup = t_sequential / t_tuned
    summary = {
        "p": p, "n": n, "points": points, "dtype": "float64",
        "tol": tol, "max_iters": max_iters, "repeats": repeats,
        "t_sequential_s": round(t_sequential, 4),
        "t_batched_matched_s": round(t_matched, 4),
        "t_batched_s": round(t_tuned, 4),
        "speedup_vs_sequential": round(speedup, 3),
        "speedup_matched": round(t_sequential / t_matched, 3),
        "engine": {**tuned, "schedule": "compact"},
        "segments": int(stats.segments),
        "waves": int(stats.waves),
        "pilot_lane": int(stats.pilot_lane),
        "occupancy_timeline": [int(v) for v in stats.occupancy],
        "capacity_timeline": [int(v) for v in stats.capacities],
        "mean_occupancy": round(stats.mean_occupancy, 4),
        "lane_steps": stats.lane_steps,
        "padded_lane_steps": stats.padded_lane_steps,
        "matched_bitexact": True,
        "agreement_atol": AGREEMENT_ATOL,
        "max_abs_err": max_err,
        "agrees": agrees,
        "stats_summary": stats.summary(),
        "points_detail": rows,
    }
    path = write_bench("BENCH_path_batch", summary)
    print(f"# {points}-point f64 path at p={p}: sequential "
          f"{t_sequential:.2f}s, matched batched {t_matched:.2f}s "
          f"({t_sequential / t_matched:.2f}x, bit-exact), tuned batched "
          f"{t_tuned:.2f}s ({speedup:.2f}x) — {stats.summary()}; "
          f"tuned max |dOmega| {max_err:.2e} (atol {AGREEMENT_ATOL:g}) "
          f"-> {path}")
    assert agrees, (
        f"tuned batched path disagrees with the sequential reference: "
        f"max err {max_err:.2e} > {AGREEMENT_ATOL:g}")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shape for CI (p=128, n=320)")
    ap.add_argument("--p", type=int, default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--points", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    p = args.p or (128 if args.quick else 512)
    n = args.n or (320 if args.quick else 1024)
    return run(p=p, n=n, points=args.points, repeats=args.repeats)


if __name__ == "__main__":
    main()
