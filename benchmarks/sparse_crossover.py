"""Dense vs block-sparse Ω-product crossover sweep (the matops layer's
calibration artifact).

Times the dense ``omega @ b`` against the block-gather path
(``core.matops.masked_matmul`` — the jittable jnp fallback of the Pallas
block-CSR kernel) over a block-density grid, then

  * reports the measured crossover density (largest density where the
    sparse path still wins),
  * calibrates ``core.costmodel.BlockSparseModel`` from the measurements
    and compares its predicted crossover against the measured one (the
    shipped defaults are conservative: model <= measured, so
    ``sparse_matmul="auto"`` never routes sparse past break-even),
  * emits results/sparse_crossover.csv + results/sparse_crossover.json
    (the JSON is uploaded as a CI artifact to track the perf trajectory).

  PYTHONPATH=src python -m benchmarks.sparse_crossover [--quick]

Interpret-mode CPU numbers: the block-gather path here is pure jnp (no
Pallas interpret overhead), so the speedups reflect real skipped work.
"""
from __future__ import annotations

import argparse
from functools import partial

import numpy as np

from .common import emit, timeit, write_bench

DENSITIES = (0.02, 0.05, 0.1, 0.2, 0.4, 0.7, 1.0)


def _block_sparse_operand(rng, p, bs, density):
    a = rng.standard_normal((p, p)).astype(np.float32)
    nb = p // bs
    keep = rng.random((nb, nb)) < density
    np.fill_diagonal(keep, True)        # iterates always keep the diagonal
    for r in range(nb):
        for c in range(nb):
            if not keep[r, c]:
                a[r * bs:(r + 1) * bs, c * bs:(c + 1) * bs] = 0
    return a, float(keep.mean())


def sweep(ps, bs, densities, repeats=3):
    import jax
    import jax.numpy as jnp

    from repro.core import matops

    rng = np.random.default_rng(0)
    rows = []
    for p in ps:
        m = p
        b = jnp.asarray(rng.standard_normal((p, m)).astype(np.float32))
        dense_fn = jax.jit(lambda a_, b_: a_ @ b_)
        for density in densities:
            a_np, eff_density = _block_sparse_operand(rng, p, bs, density)
            a = jnp.asarray(a_np)
            mask = matops.block_mask(a, bs)
            cap = max(1, int(np.asarray(mask).sum()))
            sparse_fn = jax.jit(partial(matops.masked_matmul,
                                        block_size=bs, capacity=cap))
            t_dense, _ = timeit(dense_fn, a, b, repeats=repeats)
            t_sparse, out = timeit(sparse_fn, a, b, mask, repeats=repeats)
            err = float(jnp.max(jnp.abs(out - a @ b)))
            rows.append({
                "p": p, "m": m, "block_size": bs, "density": eff_density,
                "t_dense": t_dense, "t_sparse": t_sparse,
                "speedup": round(t_dense / t_sparse, 3),
                "max_abs_err": err,
            })
            print(f"  p={p} density={eff_density:.3f} "
                  f"dense {t_dense*1e3:8.2f}ms  sparse {t_sparse*1e3:8.2f}ms "
                  f"speedup {t_dense/t_sparse:5.2f}x")
    return rows


def measured_crossover(rows, p):
    """Largest density of the sparse path's winning streak from the bottom
    of the sweep (robust to a noisy one-off win at high density, which the
    plain max-over-wins would report as the crossover)."""
    cross = 0.0
    for r in sorted((r for r in rows if r["p"] == p),
                    key=lambda r: r["density"]):
        if r["t_sparse"] >= r["t_dense"]:
            break
        cross = r["density"]
    return cross


def run(argv=None):
    from repro.core.costmodel import (
        BlockSparseModel,
        calibrate_block_model,
        crossover_density,
    )

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sizes for CI (artifact trend tracking)")
    ap.add_argument("--block-size", type=int, default=128)
    args, _ = ap.parse_known_args(argv)
    ps = (512,) if args.quick else (1024, 2048)
    bs = min(args.block_size, ps[0] // 8)   # keep a usable mask resolution

    rows = sweep(ps, bs, DENSITIES)
    emit("sparse_crossover", rows)

    calibrated = calibrate_block_model(rows)
    default = BlockSparseModel()
    summary = {"rows": rows, "block_size": bs, "per_p": {}}
    for p in ps:
        meas = measured_crossover(rows, p)
        model_default = crossover_density(p, p, bs, model=default)
        model_calibrated = crossover_density(p, p, bs, model=calibrated)
        summary["per_p"][str(p)] = {
            "measured_crossover": meas,
            "model_crossover_default": model_default,
            "model_crossover_calibrated": model_calibrated,
            "auto_is_conservative": model_default <= meas + 1e-9,
        }
        print(f"p={p}: measured crossover {meas:.3f} | model default "
              f"{model_default:.3f} | model calibrated "
              f"{model_calibrated:.3f}")
    summary["calibrated_model"] = {
        "dense_eff": calibrated.dense_eff,
        "sparse_eff": calibrated.sparse_eff,
        "gather_eff": calibrated.gather_eff,
    }
    path = write_bench("sparse_crossover", summary)
    print(f"# wrote {path}")
    return rows


if __name__ == "__main__":
    run()
