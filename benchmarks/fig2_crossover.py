"""Paper Figure 2: Cov vs Obs runtime as n grows (fixed p).

Measured single-process runtimes of both variants on CPU-sized problems
plus the analytic Lemma-3.1/3.5 model evaluated at the PAPER's scale
(p=40k, 16 nodes) so the crossover structure is visible at both scales.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import graphs
from repro.core.costmodel import EDISON, ProblemShape, cov_costs, \
    obs_costs
from repro.core.prox import fit_reference

from .common import emit, timeit


def run():
    rows = []
    p = 192
    for n in [48, 96, 192, 384, 768, 1536]:
        prob = graphs.make_problem("chain", p=p, n=n, seed=0)
        t_cov, r_cov = timeit(
            lambda: fit_reference(jnp.asarray(prob.s), 0.15, 0.05,
                                  tol=1e-5, max_iters=150), repeats=2)
        t_obs, r_obs = timeit(
            lambda: fit_reference(jnp.asarray(prob.x), 0.15, 0.05,
                                  variant="obs", tol=1e-5, max_iters=150),
            repeats=2)
        rows.append({
            "p": p, "n": n,
            "t_cov_s": round(t_cov, 4), "t_obs_s": round(t_obs, 4),
            "iters_cov": int(r_cov.iters), "iters_obs": int(r_obs.iters),
            "cov_faster": t_cov < t_obs,
        })
    emit("fig2_crossover_measured", rows)

    # analytic overlay at paper scale (p=40k, 16 nodes, Edison constants)
    arows = []
    for n in [100, 200, 400, 800, 1600, 3200, 6400, 12800]:
        shape = ProblemShape(p=40000, n=n, d=4.0, s=20, t=8.0)
        tc = cov_costs(shape, 32, 1, 1, EDISON).total
        to = obs_costs(shape, 32, 1, 1, EDISON).total
        arows.append({"p": 40000, "n": n, "model_t_cov_s": round(tc, 2),
                      "model_t_obs_s": round(to, 2),
                      "cov_faster": tc < to})
    emit("fig2_crossover_model", arows)
    return rows + arows
