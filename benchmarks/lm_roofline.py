"""LM-zoo roofline table: reads the dry-run records (results/*.jsonl)
and renders the §Roofline table; falls back to the analytic model when
no dry-run artifact exists yet."""
from __future__ import annotations

import glob
import json
import os

from .common import emit


def load_records():
    import repro.configs as C
    recs = {}
    for path in sorted(glob.glob(os.path.join("results", "dryrun*.jsonl"))):
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                recs[(C.canon(r["arch"]), r["shape"], r["mesh"])] = r
    valid = {(C.canon(a), s) for a, s in C.cells()}
    return [r for k, r in recs.items() if (k[0], k[1]) in valid]


def run():
    recs = load_records()
    if not recs:
        print("# no dry-run records yet — run "
              "`python -m repro.launch.dryrun --all --out "
              "results/dryrun_baseline.jsonl` first")
        return []
    rows = []
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "t_compute_ms": round(1e3 * r["t_compute"], 2),
            "t_memory_ms": round(1e3 * r["t_memory"], 2),
            "t_collective_ms": round(1e3 * r["t_collective"], 2),
            "dominant": r["dominant"],
            "useful_frac": round(r["useful_frac"], 3),
            "mfu_at_bound_pct": round(100 * r["mfu_at_bound"], 2),
            "fits_hbm": r["fits_hbm"],
            "bytes_per_dev_gb": round(r["total_bytes_per_dev"] / 1e9, 2),
        })
    emit("lm_roofline", rows)
    return rows
