"""Warm-started regularization path vs cold restarts (the facade's
headline speedup): fit the same descending lam1 grid twice through
``ConcordEstimator.fit_path`` — once warm-starting each point from the
previous solution (and reusing the jitted solve), once cold — and compare
cumulative outer iterations, line-search trials and wall time.  The final
objectives must agree; the iteration counts must not."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import graphs
from repro.estimator import ConcordEstimator, SolverConfig

from .common import emit


def run():
    prob = graphs.make_problem("chain", p=96, n=240, seed=0)
    s = jnp.asarray(prob.s)
    lam1_grid = np.geomspace(0.4, 0.08, 8)
    est = ConcordEstimator(
        lam2=0.05,
        config=SolverConfig(backend="reference", variant="cov",
                            tol=1e-6, max_iters=400))

    warm = est.fit_path(s=s, n_samples=240, lam1_grid=lam1_grid)
    cold = est.fit_path(s=s, n_samples=240, lam1_grid=lam1_grid,
                        warm_start=False)

    rows = []
    max_obj_gap = 0.0
    for w, c in zip(warm, cold):
        gap = abs(w.objective - c.objective)
        max_obj_gap = max(max_obj_gap, gap)
        rows.append({
            "lam1": round(w.lam1, 4),
            "warm_iters": w.iters, "cold_iters": c.iters,
            "warm_ls": w.ls_total, "cold_ls": c.ls_total,
            "warm_t_s": round(w.wall_time_s, 4),
            "cold_t_s": round(c.wall_time_s, 4),
            "obj_gap": round(gap, 8),
        })
    emit("path_warmstart", rows)
    print(f"# warm path: {warm.total_iters} outer iters / "
          f"{warm.total_ls} ls trials; cold: {cold.total_iters} / "
          f"{cold.total_ls}  "
          f"({cold.total_iters / max(warm.total_iters, 1):.2f}x iters saved; "
          f"max objective gap {max_obj_gap:.2e})")
    assert warm.total_iters < cold.total_iters, \
        "warm-started path must take fewer cumulative outer iterations"
    return rows
