"""Static-predicted vs analytic bytes-on-wire (BENCH_comm_volume.json).

For every 1.5D ring product of ``comm.matmul1p5d`` / ``comm.sparse1p5d``
and the compressed collectives of ``comm.collectives``, across a
(P, c_x, c_omega, dtype) sweep, emits two independently derived byte
counts per outer invocation:

  * ``static_bytes``  — the comm engine's count: the schedule is traced
    with ``make_jaxpr(axis_env=...)`` (no devices) and each collective's
    wire bytes are summed from the jaxpr's payload shapes, permutation
    tables and scan lengths;
  * ``analytic_bytes`` — ``core.costmodel``'s closed-form volume (the
    paper's W term made exact, per processor along the critical path).

The two counts must MATCH EXACTLY (integer/fraction equality, no
tolerance) for every row: this is CA303 run as a benchmark artifact, and
the script exits 1 on any mismatch so the CI comm-volume job gates on it.

Emits results/BENCH_comm_volume.csv and results/BENCH_comm_volume.json.

  PYTHONPATH=src python -m benchmarks.comm_volume
"""
from __future__ import annotations

from fractions import Fraction

from .common import emit, write_bench

#: the sweep: replication off / one-sided / both / deep
GRIDS = [(4, 1, 1), (8, 2, 1), (8, 1, 2), (8, 2, 2), (16, 2, 2),
         (16, 4, 2)]
FLAVORS = ("xtx", "omega_s", "y_x", "omega_xt")
DTYPES = ("float64", "float32")
MASK_BS = 2


def _build_flavor(flavor, grid, p, n, dtype, *, masked=False, bs=MASK_BS):
    """Zero-arg build thunk for one ring product (arrays are created
    inside the thunk so they materialise under the engine's enable_x64)."""
    def build():
        import jax.numpy as jnp

        from repro.comm import matmul1p5d as mm
        from repro.comm import sparse1p5d as sp
        from repro.core import matops

        axis_env = (("i", grid.n_i), ("j", grid.c_omega),
                    ("k", grid.c_x))
        dt = jnp.dtype(dtype)
        blk_x, blk_om = p // grid.n_x, p // grid.n_om
        if flavor == "xtx":
            x = jnp.linspace(-1.0, 1.0, n * blk_x,
                             dtype=dt).reshape(n, blk_x)
            return {"fn": lambda a: mm.xtx_local(a, grid), "args": (x,),
                    "axis_env": axis_env}
        if flavor == "omega_s":
            om = jnp.eye(blk_om, p, dtype=dt)
            s = jnp.ones((p, blk_x), dt)
            if masked:
                policy = matops.MatmulPolicy(mode="on", block_size=bs,
                                             threshold=0.5)
                mask = matops.block_mask(om, bs)
                return {"fn": lambda a, m, b: sp.omega_s_local_sparse(
                            a, m, b, grid, policy=policy,
                            canonical="omegalike"),
                        "args": (om, mask, s), "axis_env": axis_env}
            return {"fn": lambda a, b: mm.omega_s_local(
                        a, b, grid, canonical="omegalike"),
                    "args": (om, s), "axis_env": axis_env}
        if flavor == "y_x":
            y = jnp.ones((blk_om, n), dt)
            x = jnp.ones((n, blk_x), dt)
            return {"fn": lambda a, b: mm.y_x_local(a, b, grid),
                    "args": (y, x), "axis_env": axis_env}
        if flavor == "omega_xt":
            om = jnp.eye(blk_om, p, dtype=dt)
            xt = jnp.ones((blk_x, n), dt)
            if masked:
                policy = matops.MatmulPolicy(mode="on", block_size=bs,
                                             threshold=0.5)
                mask = matops.block_mask(om, bs)
                return {"fn": lambda a, m, b: sp.omega_xt_local_sparse(
                            a, m, b, grid, policy=policy),
                        "args": (om, mask, xt), "axis_env": axis_env}
            return {"fn": lambda a, b: mm.omega_xt_local(a, b, grid),
                    "args": (om, xt), "axis_env": axis_env}
        raise ValueError(flavor)
    return build


def ring_rows():
    from repro.analysis import commpass
    from repro.analysis.rules import DEFAULT_PROFILE
    from repro.comm.grid import Grid1p5D
    from repro.core.costmodel import comm_volume

    rows = []
    for P, c_x, c_omega in GRIDS:
        grid = Grid1p5D(P, c_x, c_omega)
        p, n = 4 * P, 8
        for flavor in FLAVORS:
            for dtype in DTYPES:
                masked_opts = ([False, True]
                               if flavor in ("omega_s", "omega_xt")
                               and dtype == "float64" else [False])
                for masked in masked_opts:
                    build = _build_flavor(flavor, grid, p, n, dtype,
                                          masked=masked)
                    entry = {"name": "bench", "path": "bench",
                             "axis_names": ("i", "j", "k"),
                             "build": build}
                    findings, record = commpass.run_entry(
                        entry, DEFAULT_PROFILE)
                    vol = comm_volume(
                        p, n, P, c_x, c_omega, flavor=flavor,
                        dtype=dtype,
                        masked=(masked and flavor == "omega_s"),
                        block_size=MASK_BS)
                    static = (None if record is None
                              else record["static_bytes"])
                    rows.append({
                        "flavor": flavor + ("_masked" if masked else ""),
                        "P": P, "c_x": c_x, "c_omega": c_omega,
                        "p": p, "n": n, "dtype": dtype,
                        "rounds": vol.rounds,
                        "static_bytes": static,
                        "analytic_bytes": str(vol.total),
                        "ring_bytes": str(vol.ring_bytes),
                        "finish_bytes": str(vol.finish_bytes),
                        "match": (static is not None and not findings
                                  and Fraction(static) == vol.total),
                    })
    return rows


def collective_rows():
    from repro.analysis import commpass
    from repro.analysis.rules import DEFAULT_PROFILE
    from repro.comm import collectives as cc

    rows = []
    for entry in cc.ANALYSIS_ENTRIES:
        findings, record = commpass.run_entry(entry, DEFAULT_PROFILE)
        contract = record["contract"] if record else {}
        rows.append({
            "flavor": entry["name"].rsplit(".", 1)[-1],
            "P": cc._RING_EXTENT, "c_x": "", "c_omega": "",
            "p": "", "n": "", "dtype": "wire-compressed",
            "rounds": contract.get("rounds", ""),
            "static_bytes": record and record["static_bytes"],
            "analytic_bytes": contract.get("expected_bytes"),
            "ring_bytes": "", "finish_bytes": "",
            "match": (not findings and record is not None
                      and record["static_bytes"]
                      == contract.get("expected_bytes")),
        })
    return rows


def main() -> int:
    rows = ring_rows() + collective_rows()
    emit("BENCH_comm_volume", rows)
    mismatches = [r for r in rows if not r["match"]]
    report = {
        "rows": rows,
        "n_rows": len(rows),
        "n_mismatches": len(mismatches),
        "exact_match": not mismatches,
    }
    out = write_bench("BENCH_comm_volume", report)
    print(f"wrote {out}: {len(rows)} rows, "
          f"{len(mismatches)} mismatch(es)")
    if mismatches:
        for r in mismatches:
            print(f"MISMATCH: {r}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
