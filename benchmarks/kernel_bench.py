"""Pallas kernel microbenchmarks: interpret-mode allclose + flop/byte
accounting per kernel configuration (the wall times are CPU-interpret
and NOT indicative of TPU speed — the flop/byte model is the artifact)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import emit


def run():
    rng = np.random.default_rng(0)
    rows = []

    # fused prox: one pass of p^2 state + stats vs 3 separate passes
    for p in [256, 512]:
        z = rng.standard_normal((p, p)).astype(np.float32)
        mask = np.eye(p, dtype=np.float32)
        out, *stats = ops.fused_prox_stats(jnp.asarray(z),
                                           jnp.asarray(mask), 0.3)
        r = ref.fused_prox_stats(jnp.asarray(z), jnp.asarray(mask), 0.3)
        ok = bool(np.allclose(np.asarray(out), np.asarray(r[0]),
                              rtol=1e-5))
        rows.append({"kernel": "fused_prox", "shape": f"{p}x{p}",
                     "allclose": ok,
                     "bytes_one_pass": 2 * 4 * p * p,
                     "bytes_unfused_3pass": 6 * 4 * p * p})

    # block-sparse matmul: flops saved vs dense at various block density
    p, m, bs = 512, 256, 64
    for density in [0.1, 0.3, 1.0]:
        a = rng.standard_normal((p, p)).astype(np.float32)
        keep = rng.random((p // bs, p // bs)) < density
        for r_ in range(p // bs):
            for c_ in range(p // bs):
                if not keep[r_, c_]:
                    a[r_ * bs:(r_ + 1) * bs, c_ * bs:(c_ + 1) * bs] = 0
        vals, rowi, coli = ref.dense_to_block_csr(a, bs)
        b = rng.standard_normal((p, m)).astype(np.float32)
        out = ops.blocksparse_matmul(jnp.asarray(vals), jnp.asarray(rowi),
                                     jnp.asarray(coli), jnp.asarray(b))
        ok = bool(np.allclose(np.asarray(out), a @ b, rtol=1e-4,
                              atol=1e-4))
        dense_flops = 2 * p * p * m
        sparse_flops = 2 * vals.shape[0] * bs * bs * m
        rows.append({"kernel": "blocksparse_matmul",
                     "shape": f"{p}x{p}@{p}x{m}",
                     "allclose": ok,
                     "block_density": density,
                     "flops_dense": dense_flops,
                     "flops_sparse": sparse_flops,
                     "flop_saving": round(1 - sparse_flops / dense_flops,
                                          3)})

    # flash attention: O(L^2) bytes (naive) vs O(L*block) VMEM footprint
    for L, window in [(256, None), (512, 128)]:
        B, H, D = 1, 4, 64
        q = rng.standard_normal((B, H, L, D)).astype(np.float32)
        k = rng.standard_normal((B, H, L, D)).astype(np.float32)
        v = rng.standard_normal((B, H, L, D)).astype(np.float32)
        o = ops.flash_attention(jnp.asarray(q), jnp.asarray(k),
                                jnp.asarray(v), window=window,
                                block_q=128, block_k=128)
        r = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          window=window)
        ok = bool(np.allclose(np.asarray(o), np.asarray(r), rtol=2e-3,
                              atol=2e-3))
        naive = 4 * B * H * L * L
        flash = 4 * B * H * L * 128 * 2
        skipped = 0.0 if window is None else 1 - min(1.0, window * 2 / L)
        rows.append({"kernel": "flash_attention", "shape": f"L={L}",
                     "allclose": ok, "window": window or 0,
                     "logits_bytes_naive": naive,
                     "vmem_bytes_flash": flash,
                     "tile_skip_frac": round(skipped, 3)})
    emit("kernel_bench", rows)
    return rows
