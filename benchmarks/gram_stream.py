"""Streamed vs dense Gram benchmark (BENCH_gram_stream.json).

For each (transform, chunk_rows) cell the same scenario stream is reduced
two ways:

  * dense   — materialize the full (n, p) X once, one-shot XᵀX/n of the
              transformed matrix (the only mode the repo had before the
              data subsystem);
  * streamed— ``data.gram.GramAccumulator`` over the seeded chunked
              sampler: X never exists, resident working set is one chunk
              plus the (p, p) f64 state.

Reported per cell: throughput (rows/s), the streamed/dense wall ratio,
a peak-memory proxy (resident bytes of each mode — chunk+state vs full
matrix+state), and the f64 agreement gap (gated at 1e-10; the benchmark
doubles as an integration check).  Emits results/BENCH_gram_stream.csv
and results/BENCH_gram_stream.json — the JSON is uploaded as a CI
artifact to track the streaming layer's throughput trajectory.

  PYTHONPATH=src python -m benchmarks.gram_stream [--quick]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from .common import emit, write_bench

AGREEMENT_ATOL = 1e-10


def _dense_gram(x: np.ndarray, transform: str) -> np.ndarray:
    from repro.data.transforms import rank_transform_column
    x = np.asarray(x, np.float64)
    if transform == "center":
        x = x - x.mean(0)
    elif transform == "standardize":
        x = (x - x.mean(0)) / x.std(0)
    elif transform == "rank":
        x = np.stack([rank_transform_column(x[:, j])
                      for j in range(x.shape[1])], axis=1)
    return x.T @ x / x.shape[0]


def run(p: int = 256, n: int = 200_000, family: str = "erdos_renyi",
        transforms=("none", "standardize", "rank"),
        chunk_grid=(1024, 8192, 65536), repeats: int = 2):
    from repro.data import compute_gram, make_scenario

    sc = make_scenario(family, p, cond=10.0, seed=0)
    rows, max_err = [], 0.0
    state_bytes = p * p * 8
    for transform in transforms:
        for chunk_rows in chunk_grid:
            src = sc.source(n, chunk_rows=chunk_rows, seed=1)

            def run_stream():
                return compute_gram(src, transform=transform,
                                    chunk_rows=chunk_rows)

            def run_dense():
                x = sc.sample(n, seed=1, chunk_rows=chunk_rows)
                return _dense_gram(x, transform)

            t_s, t_d = [], []
            for _ in range(repeats):
                t0 = time.perf_counter()
                g = run_stream()
                t_s.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                ref = run_dense()
                t_d.append(time.perf_counter() - t0)
            t_stream = float(np.median(t_s))
            t_dense = float(np.median(t_d))
            err = float(np.abs(g.s - ref).max())
            max_err = max(max_err, err)
            # resident-set proxy: what each mode must hold at once.  The
            # chunk is capped at n rows; the rank transform's true
            # resident set is its n x w column-sweep buffer (it also
            # uses n*p*8 of scratch DISK, not RAM)
            eff_chunk = min(chunk_rows, n)
            stream_bytes = eff_chunk * p * 8 * 2 + state_bytes
            if transform == "rank":
                from repro.data.gram import RANK_BUDGET_BYTES
                w = max(1, min(p, RANK_BUDGET_BYTES // (n * 8)))
                stream_bytes = max(stream_bytes, n * w * 8 + state_bytes)
            dense_bytes = n * p * 8 + state_bytes
            rows.append({
                "family": family, "transform": transform,
                "p": p, "n": n, "chunk_rows": chunk_rows,
                "n_chunks": int(g.n_chunks),
                "t_streamed_s": round(t_stream, 4),
                "t_dense_s": round(t_dense, 4),
                "stream_rows_per_s": round(n / max(t_stream, 1e-9), 1),
                "wall_ratio": round(t_stream / max(t_dense, 1e-9), 3),
                "peak_bytes_streamed": stream_bytes,
                "peak_bytes_dense": dense_bytes,
                "memory_ratio": round(dense_bytes / stream_bytes, 2),
                "max_abs_err": err,
            })
            print(f"  {family}/{transform:11s} chunk={chunk_rows:6d}: "
                  f"streamed {t_stream:.2f}s vs dense {t_dense:.2f}s, "
                  f"mem {dense_bytes / stream_bytes:.1f}x smaller, "
                  f"err {err:.1e}")
    emit("BENCH_gram_stream", rows)

    agrees = max_err <= AGREEMENT_ATOL
    summary = {
        "family": family, "p": p, "n": n,
        "gram_dtype": "float64",
        "agreement_atol": AGREEMENT_ATOL,
        "max_abs_err": max_err,
        "agrees": agrees,
        "best_memory_ratio": max(r["memory_ratio"] for r in rows),
        "cells": rows,
    }
    path = write_bench("BENCH_gram_stream", summary)
    print(f"# streamed Gram at p={p}, n={n}: up to "
          f"{summary['best_memory_ratio']:.0f}x smaller resident set; "
          f"max |dS| {max_err:.2e} (atol {AGREEMENT_ATOL:g}) -> {path}")
    assert agrees, (
        f"streamed Gram disagrees with dense: {max_err:.2e} > "
        f"{AGREEMENT_ATOL:g}")
    return summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shape for CI (p=64, n=20000)")
    ap.add_argument("--p", type=int, default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--family", default="erdos_renyi")
    ap.add_argument("--repeats", type=int, default=2)
    args = ap.parse_args(argv)
    p = args.p or (64 if args.quick else 256)
    n = args.n or (20_000 if args.quick else 200_000)
    chunks = (512, 4096) if args.quick else (1024, 8192, 65536)
    return run(p=p, n=n, family=args.family, chunk_grid=chunks,
               repeats=args.repeats)


if __name__ == "__main__":
    main()
