"""Quickstart: estimate a sparse inverse covariance matrix with
HP-CONCORD on synthetic data via the ``repro.estimator`` facade.

  PYTHONPATH=src python examples/quickstart.py
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/quickstart.py   # distributed
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graphs
from repro.estimator import ConcordEstimator, SolverConfig


def main():
    p, n = 120, 300
    prob = graphs.make_problem("chain", p=p, n=n, seed=0)
    print(f"problem: chain graph, p={p}, n={n}, "
          f"{len(jax.devices())} device(s)")

    # single-device reference backend
    ref = ConcordEstimator(
        lam1=0.15, lam2=0.05,
        config=SolverConfig(backend="reference", variant="cov",
                            tol=1e-6, max_iters=300),
    ).fit_cov(jnp.asarray(prob.s), n_samples=n)
    ppv, fdr = graphs.ppv_fdr(np.asarray(ref.omega_), prob.omega0)
    print(f"reference  : {ref.report_.summary()}")
    print(f"             PPV={ppv:.3f} FDR={fdr:.3f}")

    # "auto" backend: engine, variant and replication chosen by the paper's
    # cost model (reference on one device, distributed 1.5D otherwise)
    auto = ConcordEstimator(
        lam1=0.15, lam2=0.05,
        config=SolverConfig(backend="auto", tol=1e-6, max_iters=300),
    ).fit(jnp.asarray(prob.x))
    ppv, fdr = graphs.ppv_fdr(np.asarray(auto.omega_), prob.omega0)
    print(f"auto       : {auto.report_.summary()}")
    print(f"             PPV={ppv:.3f} FDR={fdr:.3f}")

    diff = np.abs(np.asarray(auto.omega_) - np.asarray(ref.omega_)).max()
    print(f"max |auto - reference| = {diff:.2e}")

    # warm-started regularization path + BIC model selection in one call
    path = ConcordEstimator(
        lam2=0.05,
        config=SolverConfig(backend="reference", variant="cov",
                            tol=1e-6, max_iters=300),
    ).fit_path(s=jnp.asarray(prob.s), n_samples=n,
               lam1_grid=[0.3, 0.25, 0.2, 0.15, 0.1])
    best = path.best_bic()
    print(f"path       : {len(path)} fits, {path.total_iters} total iters "
          f"(warm-started); BIC-best lam1={best.lam1:g}")

    # composable penalties (repro.core.penalty): swap the prox operator
    # without touching the solver — here SCAD's unbiased tails
    scad = ConcordEstimator(
        lam1=0.15, lam2=0.05, penalty="scad:3.7",
        config=SolverConfig(backend="reference", variant="cov",
                            tol=1e-6, max_iters=300),
    ).fit_cov(jnp.asarray(prob.s), n_samples=n)
    print(f"scad       : {scad.report_.summary()}")

    # two-stage adaptive-lasso refit: l1 stage-1 path, then each point
    # refit with weights 1/(|omega_hat| + eps) from its own stage-1
    # estimate (weighted_l1 specs under the hood)
    apath = ConcordEstimator(
        lam2=0.05,
        config=SolverConfig(backend="reference", variant="cov",
                            tol=1e-6, max_iters=300),
    ).fit_path(s=jnp.asarray(prob.s), n_samples=n,
               lam1_grid=[0.3, 0.25, 0.2, 0.15, 0.1], adaptive=True)
    abest = apath.best_bic()
    ppv, fdr = graphs.ppv_fdr(np.asarray(abest.omega), prob.omega0)
    ppv1, fdr1 = graphs.ppv_fdr(
        np.asarray(apath.stage1.best_bic().omega), prob.omega0)
    print(f"adaptive   : 2-stage refit, BIC-best lam1={abest.lam1:g}; "
          f"PPV {ppv1:.3f}->{ppv:.3f}, FDR {fdr1:.3f}->{fdr:.3f}")


if __name__ == "__main__":
    main()
