"""Quickstart: estimate a sparse inverse covariance matrix with
HP-CONCORD on synthetic data, auto-tuned by the paper's cost model.

  PYTHONPATH=src python examples/quickstart.py
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/quickstart.py   # distributed
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed, graphs
from repro.core.prox import fit_reference


def main():
    p, n = 120, 300
    prob = graphs.make_problem("chain", p=p, n=n, seed=0)
    print(f"problem: chain graph, p={p}, n={n}, "
          f"{len(jax.devices())} device(s)")

    # single-device reference
    ref = fit_reference(jnp.asarray(prob.s), lam1=0.15, lam2=0.05,
                        tol=1e-6, max_iters=300)
    ppv, fdr = graphs.ppv_fdr(np.asarray(ref.omega), prob.omega0)
    print(f"reference : iters={int(ref.iters)} "
          f"objective={float(ref.g_final):.4f} PPV={ppv:.3f} FDR={fdr:.3f}")

    # distributed, variant + replication chosen by the cost model
    res = distributed.fit(x=jnp.asarray(prob.x), lam1=0.15, lam2=0.05,
                          tol=1e-6, max_iters=300)
    ppv, fdr = graphs.ppv_fdr(np.asarray(res.omega), prob.omega0)
    print(f"distributed: variant={res.variant} "
          f"(c_x={res.grid.c_x}, c_omega={res.grid.c_omega}) "
          f"iters={int(res.iters)} objective={float(res.g_final):.4f} "
          f"PPV={ppv:.3f} FDR={fdr:.3f}")

    diff = np.abs(np.asarray(res.omega) - np.asarray(ref.omega)).max()
    print(f"max |distributed - reference| = {diff:.2e}")


if __name__ == "__main__":
    main()
