"""Figure-3-style replication study driver: sweep (c_X, c_Omega) on
however many devices this process has and print the runtime heatmap
next to the cost model's prediction.  Uses the ``repro.estimator``
facade with the distributed backend pinned per sweep point.

  XLA_FLAGS=--xla_force_host_platform_device_count=16 \
      PYTHONPATH=src python examples/replication_study.py
"""
import jax
import jax.numpy as jnp

from repro.core import graphs
from repro.core.costmodel import Machine, ProblemShape, obs_costs
from repro.estimator import ConcordEstimator, SolverConfig


def main():
    P = len(jax.devices())
    prob = graphs.make_problem("chain", p=64, n=32, seed=0)
    shape = ProblemShape(p=64, n=32, d=3.0, s=30, t=6.0)
    x = jnp.asarray(prob.x)
    print(f"{P} devices; p=64 n=32 chain graph\n")
    print(f"{'c_x':>4} {'c_om':>4} {'measured_s':>11} {'model_s':>9}")
    cands = []
    c = 1
    while c <= P:
        cands.append(c)
        c *= 2
    results = []
    for cx in cands:
        for co in cands:
            if cx * co > P or P % (cx * co):
                continue
            est = ConcordEstimator(
                lam1=0.2, lam2=0.05,
                config=SolverConfig(backend="distributed", variant="obs",
                                    c_x=cx, c_omega=co,
                                    tol=1e-5, max_iters=50))
            est.fit(x)                      # warm-up (compile)
            t = est.fit(x).report_.wall_time_s
            model = obs_costs(shape, P, cx, co, Machine()).total
            results.append((t, cx, co))
            print(f"{cx:>4} {co:>4} {t:>11.4f} {model:>9.2e}")
    best = min(results)
    base = [r for r in results if r[1] == 1 and r[2] == 1][0]
    print(f"\nbest (c_x={best[1]}, c_omega={best[2]}): "
          f"{base[0] / best[0]:.2f}x over no-replication")


if __name__ == "__main__":
    main()
