"""Section-5 analogue: functional-region recovery from a partial
correlation graph (the paper's fMRI case study, synthesized).

Ground truth: variables live on a 2D grid (the 'cortex'); blocks of the
grid form 'functional regions' with strong intra-region partial
correlations.  Pipeline (exactly the paper's):
  (i)  HP-CONCORD estimate over a small (lam1, lam2) grid;
  (ii) persistent-homology watershed clustering of the vertex-degree
       field + the Louvain-class label-propagation baseline + the
       thresholded-covariance baseline;
  (iii) modified Jaccard score against the true regions.

  PYTHONPATH=src python examples/brain_clustering.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import clustering, graphs
from repro.estimator import ConcordEstimator, SolverConfig


def make_region_problem(side=12, region=4, n=600, seed=0):
    """Variables on a side x side grid; region x region blocks are the
    true clusters; neighbors within a region are partially correlated."""
    p = side * side
    omega = np.eye(p, dtype=np.float32)
    labels = np.zeros(p, dtype=np.int64)
    nbrs = clustering.grid_neighbors(side, side)
    for idx in range(p):
        r, c = divmod(idx, side)
        labels[idx] = (r // region) * (side // region) + (c // region)
        for j in nbrs[idx]:
            if labels[j] == labels[idx] if j < idx else False:
                pass
    for i in range(p):
        for j in nbrs[i]:
            if j > i:
                ri, ci = divmod(i, side)
                rj, cj = divmod(j, side)
                same = (ri // region == rj // region and
                        ci // region == cj // region)
                if same:
                    omega[i, j] = omega[j, i] = -0.28
    # ensure diagonal dominance
    d = np.abs(omega).sum(1) - 1.0
    omega[np.diag_indices(p)] = d + 1.0
    x = graphs.sample_gaussian(omega, n, seed=seed + 1)
    return omega, labels, x, nbrs, side


def main():
    omega0, labels, x, nbrs, side = make_region_problem()
    p = omega0.shape[0]
    s = jnp.asarray((x.T @ x) / x.shape[0])
    truth_k = labels.max() + 1
    print(f"synthetic cortex: p={p} ({side}x{side} grid), "
          f"{truth_k} true regions")

    # (i) HP-CONCORD over the (lam1, lam2) grid: one warm-started
    #     regularization path per lam2 through the estimator facade
    config = SolverConfig(backend="reference", variant="cov",
                          tol=1e-5, max_iters=250)
    best = None
    for lam2 in (0.05, 0.1):
        path = ConcordEstimator(lam2=lam2, config=config).fit_path(
            s=s, n_samples=x.shape[0],
            lam1_grid=(0.12, 0.16, 0.2, 0.25), score_bic=False)
        for rep in path:
            sup = graphs.support(np.asarray(rep.omega), tol=1e-4)
            sup = sup | sup.T
            deg = clustering.degrees_from_support(sup)
            for eps in (0.0, 1.0, 2.0):
                ph = clustering.persistence_watershed(
                    deg.astype(float), nbrs, eps=eps)
                score = clustering.modified_jaccard(ph, labels)
                if best is None or score > best[0]:
                    best = (score, rep.lam1, lam2, eps, ph, sup)
    score, lam1, lam2, eps, ph, sup = best
    print(f"persistent homology: best Jaccard {score:.3f} "
          f"(lam1={lam1}, lam2={lam2}, eps={eps}, "
          f"{ph.max()+1} clusters)")

    lp = clustering.label_propagation(sup)
    print(f"label propagation  : Jaccard "
          f"{clustering.modified_jaccard(lp, labels):.3f} "
          f"({lp.max()+1} clusters)")

    # paper's baseline: thresholded sample covariance
    best_b = 0.0
    for keep in (0.02, 0.05, 0.1):
        sb = clustering.threshold_covariance_graph(np.asarray(s), keep)
        degb = clustering.degrees_from_support(sb)
        phb = clustering.persistence_watershed(degb.astype(float), nbrs,
                                               eps=1.0)
        best_b = max(best_b, clustering.modified_jaccard(phb, labels))
    print(f"thresholded-cov baseline: best Jaccard {best_b:.3f}")
    assert score >= best_b - 0.05, \
        "partial-correlation pipeline should match/beat marginal baseline"


if __name__ == "__main__":
    main()
