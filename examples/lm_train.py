"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the full production loop (checkpointing, heartbeat, straggler
monitor, deterministic data pipeline).

  PYTHONPATH=src python examples/lm_train.py [--steps 300]

The model is a scaled-down h2o-danube (same family: GQA + SWA + SwiGLU).
Loss must drop well below the uniform baseline ln(vocab).
"""
import argparse
import math
import tempfile

import repro.configs as C
from repro.train.loop import TrainerConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # ~100M params: 12L x 512d x 8H, vocab 32000
    cfg = C.get("h2o-danube-1.8b").with_(
        name="danube-100m", n_layers=12, d_model=512, n_heads=8, n_kv=4,
        d_ff=1536, window=256, remat=False, n_micro=1, dtype="float32")
    n = cfg.param_count()
    print(f"model: {cfg.name}, {n/1e6:.1f}M params")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="lm_train_ckpt_")
    tc = TrainerConfig(seq_len=256, global_batch=8, steps=args.steps,
                       peak_lr=1e-3, warmup=30, ckpt_dir=ckpt_dir,
                       ckpt_every=100, log_every=20,
                       heartbeat_path=f"{ckpt_dir}/heartbeat.json")
    res = train(cfg, tc)
    uniform = math.log(cfg.vocab)
    print(f"\nloss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"(uniform baseline {uniform:.3f})")
    assert res.losses[-1] < res.losses[0] - 0.5, "training did not learn"
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
